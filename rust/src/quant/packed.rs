//! True 1-bit weight storage and the deploy-path kernels.
//!
//! This is the execution representation behind [`crate::model::params::WeightRepr::Packed`]:
//! sign bitplanes in `u64` words plus per-group (α, μ) scales in f32
//! (the paper's fp16 *bit* accounting stays in `quant::group::QuantStats`;
//! storage here is reported at the width actually held resident),
//! an optional chain of residual bitplanes for reconstructions
//! that are not two-level per group (the Haar/transform methods), and the
//! packed GEMV/GEMM kernels the serving router and rollout engine run on.
//! The Pallas L1 kernel mirrors the same math on TPU (see
//! `python/compile/kernels/binary_matmul.py` and DESIGN.md
//! §Hardware-Adaptation).
//!
//! Kernel identity: within one group g of one row,
//!   Σ_{j∈g} (μ_g + α_g s_j) x_j = μ_g Σ_{j∈g} x_j + α_g (2 Σ_{j∈g, s_j=+1} x_j − Σ_{j∈g} x_j),
//! so a row·token dot needs only the per-group activation sums (computed once
//! per token, shared by every row) and the sum of x over *set* sign bits,
//! which the inner loop extracts a full 64-bit word at a time.

use std::cell::RefCell;

use crate::tensor::matrix::Matrix;
use crate::util::threadpool::{default_threads, parallel_for};

/// Deploy-path packing defaults: group 64 keeps scale granularity fine
/// enough that residual planes converge fast on multi-level
/// reconstructions; at most [`DEPLOY_MAX_ORDER`] bitplanes, stopping early
/// once the packed dequantization captures the method's reconstruction to
/// [`DEPLOY_REL_TOL`] relative energy.
pub const DEPLOY_GROUP_SIZE: usize = 64;
pub const DEPLOY_MAX_ORDER: usize = 4;
pub const DEPLOY_REL_TOL: f64 = 5e-3;

/// Minimum GEMM work (rows × cols × tokens × planes) before
/// [`PackedBits::for_each_row_par`] fans rows out over the persistent
/// pool. Retuned DOWN from 1e7 when per-call thread spawning was replaced
/// by pooled dispatch (~µs instead of ~100µs per call): below this the
/// serial loop still wins, above it the pool pays for itself even at
/// serve-batch sizes.
pub const PAR_WORK_MIN: f64 = 5.0e5;

/// Minimum GEMV work (rows × cols × planes) before the single-token
/// kernels parallelize across rows. Single-token dispatch is the serving
/// hot path, so the bar is a little higher than the GEMM's relative to
/// per-item cost — only genuinely large layers fan out.
pub const GEMV_PAR_MIN: f64 = 4.0e5;

/// Activation precision the packed kernels execute at — the W1A8 policy
/// knob threaded through [`crate::model::params::ParamStore`] and
/// [`crate::model::VlaConfig`] so serving, rollouts and every eval driver
/// pick it up through the `model::layers::linear`/`linear_vec` dispatch
/// with no call-site changes: `F32` streams full-precision activations
/// (W1A32), `Int8` quantizes each token to i8 with a per-token symmetric
/// scale and runs the integer inner loops ([`PackedBits::matvec_i8`] /
/// [`PackedBits::matmul_i8`]). Dense (FP) layers ignore it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ActPrecision {
    /// Full-precision f32 activations (W1A32).
    #[default]
    F32,
    /// Per-token symmetric INT8 activations (W1A8).
    Int8,
}

impl ActPrecision {
    pub fn label(&self) -> &'static str {
        match self {
            ActPrecision::F32 => "f32",
            ActPrecision::Int8 => "int8",
        }
    }

    /// Parse a CLI spelling (`f32` | `int8`, with common aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "a32" => Some(ActPrecision::F32),
            "int8" | "i8" | "a8" => Some(ActPrecision::Int8),
            _ => None,
        }
    }
}

/// How the W1A8 path obtains each token's symmetric activation scale —
/// the second activation-policy knob next to [`ActPrecision`], threaded
/// through [`crate::model::params::ParamStore`] / [`crate::model::VlaConfig`]
/// the same way:
///
/// - `PerToken`: s_tok = max|x|/127 swept at runtime per token (the PR-3
///   behavior — always exact-range, pays one max pass per token).
/// - `Static`: a calibration pass (`calib::scales`) pinned one scale per
///   layer (QuantVLA-style); the hot path skips the max sweep entirely
///   and runs the fused quantize+group-sum+bit-slice pass directly.
///   Out-of-range activations saturate at ±127 — the intended behavior
///   for calibrated scales.
///
/// Layers without a calibrated scale fall back to per-token under
/// `Static`, so a partially calibrated store still serves.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ActScaleMode {
    /// Per-token dynamic scale (max|x|/127 swept on the hot path).
    #[default]
    PerToken,
    /// Calibrated static per-layer scale (max sweep skipped).
    Static,
}

impl ActScaleMode {
    pub fn label(&self) -> &'static str {
        match self {
            ActScaleMode::PerToken => "per-token",
            ActScaleMode::Static => "static",
        }
    }

    /// Parse a CLI spelling (`per-token` | `static`, with aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "per-token" | "pertoken" | "per_token" | "dynamic" => Some(ActScaleMode::PerToken),
            "static" | "calibrated" => Some(ActScaleMode::Static),
            _ => None,
        }
    }
}

/// Precision of the attention core (per-head QKᵀ scores, softmax input
/// scaling, and the probability×V context product) — the third runtime
/// policy knob next to [`ActPrecision`] / [`ActScaleMode`], threaded
/// through [`crate::model::params::ParamStore`] /
/// [`crate::model::VlaConfig`] the same way so
/// `model::layers::attn_forward_seg` picks it up with no call-site
/// changes. `F32` keeps the PR-2 float attention; `Int8` quantizes each
/// head's Q/K/V columns to i8 with per-token symmetric scales, computes
/// scores with i32 accumulation and ONE rescale before softmax, and runs
/// an i8 context GEMM (DESIGN.md §INT8 Attention).
/// [`crate::model::MiniVla::with_act_precision`] flips this knob together
/// with the activation precision, so every `*-a8` variant inherits INT8
/// attention; [`crate::model::MiniVla::with_attn_precision`] overrides it
/// independently. Not part of the serving interface
/// ([`crate::model::VlaConfig::serve_compatible`] ignores it).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AttnPrecision {
    /// Full-precision f32 attention core.
    #[default]
    F32,
    /// Per-token symmetric INT8 scores + context GEMM.
    Int8,
}

impl AttnPrecision {
    pub fn label(&self) -> &'static str {
        match self {
            AttnPrecision::F32 => "f32",
            AttnPrecision::Int8 => "int8",
        }
    }

    /// Parse a CLI spelling (`f32` | `int8`, with common aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Some(AttnPrecision::F32),
            "int8" | "i8" => Some(AttnPrecision::Int8),
            _ => None,
        }
    }
}

/// Which inner-loop implementation the bit-sliced W1A8 popcount kernels
/// execute — the wide-lane axis of the kernel rebuild. All lanes compute
/// the identical integer sums (popcounts are exact, the plane weights are
/// powers of two), so every lane is bit-identical to the extraction
/// reference [`PackedBits::matvec_i8_extract`] on every shape, tail and
/// thread count — pinned by the forced-lane entries
/// ([`PackedBits::matvec_i8_lane`] / [`PackedBits::matmul_i8_lane`]) in
/// the unit and property walls, which exercise EVERY available lane
/// regardless of what the hot path auto-selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLane {
    /// One sign word per step (the PR-5 kernel) — the portable baseline
    /// and the fallback every other lane is checked against.
    Scalar,
    /// Portable 4×-unrolled path: four sign words per step with
    /// independent per-plane counters, so the popcount chains of
    /// neighboring words overlap instead of serializing. Runs everywhere.
    Wide4,
    /// `std::arch` AVX2 path: all 8 planes of a word are AND+popcounted
    /// in two 256-bit ops (Mula nibble-LUT popcount). Selected by runtime
    /// feature detection — never compiled-in assumed — and falls back to
    /// [`SimdLane::Wide4`] off x86_64 or when the CPU lacks AVX2.
    Avx2,
}

impl SimdLane {
    pub fn label(&self) -> &'static str {
        match self {
            SimdLane::Scalar => "scalar",
            SimdLane::Wide4 => "wide4",
            SimdLane::Avx2 => "avx2",
        }
    }

    /// Lanes executable on THIS machine: the portable lanes always, the
    /// AVX2 lane only when runtime detection reports support. Test walls
    /// iterate this so CI covers every lane the hardware can run.
    pub fn available() -> Vec<SimdLane> {
        let mut lanes = vec![SimdLane::Scalar, SimdLane::Wide4];
        if avx2_available() {
            lanes.push(SimdLane::Avx2);
        }
        lanes
    }

    /// The lane the hot path runs: the best available one, detected once
    /// per process (a `OnceLock`, so the per-call cost is one load).
    pub fn active() -> SimdLane {
        static ACTIVE: std::sync::OnceLock<SimdLane> = std::sync::OnceLock::new();
        *ACTIVE.get_or_init(|| {
            if avx2_available() {
                SimdLane::Avx2
            } else {
                SimdLane::Wide4
            }
        })
    }
}

/// Runtime AVX2 feature detection (always false off x86_64). The kernels
/// gate the `std::arch` path on this at runtime, so one binary serves
/// both AVX2 and pre-AVX2 machines with the portable lane as fallback.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// One token's INT8-quantized activations, produced by
/// [`PackedBits::quantize_act`]: q (i8), the symmetric per-token scale
/// s_tok = max|x|/127, and the per-group i32 sums of q (the μ-term of the
/// integer kernel) — built in the same sweep that quantizes, so the W1A8
/// path pays one activation pass exactly like the f32 path's
/// [`PackedBits::group_sums`].
#[derive(Clone, Debug, Default)]
pub struct ActI8 {
    pub q: Vec<i8>,
    pub scale: f32,
    pub group_sums: Vec<i32>,
    /// Column bit-slices of q, built in the same fused pass: 8 `u64`
    /// planes per 64-column word, word-major (`slices[w*8 + b]`). Plane b
    /// holds bit b of q[j] read as a `u8` (two's complement), so the sum
    /// of q over any sign-word subset S is
    ///   Σ_{b=0..6} 2^b·popcnt(S ∧ Q_b) − 128·popcnt(S ∧ Q_7)
    /// — 8 AND+POPCNT per word, branchless, integer-exact. This is what
    /// [`PackedBits::set_sum_i8_sliced`] consumes; the serial
    /// `trailing_zeros` extraction ([`PackedBits::set_sum_i8`]) stays as
    /// the bench/test reference.
    pub slices: Vec<u64>,
}

/// Per-thread scratch for the multi-token GEMMs: the activation
/// transpose, the per-token f32 group sums and the quantized-token pool
/// are reused across calls, so a coalesced server batch sweeping many
/// layers pays the allocations once instead of per layer. Buffers are
/// TAKEN out of the cell for the duration of a call and put back after
/// (re-entrancy safe: a nested GEMM on the same thread simply finds the
/// cell empty and allocates its own).
#[derive(Default)]
struct GemmScratch {
    xt: Matrix,
    gsums: Vec<f32>,
    acts: Vec<ActI8>,
    attn: Vec<AttnScratch>,
    zbufs: Vec<Vec<f32>>,
}

thread_local! {
    static GEMM_SCRATCH: RefCell<GemmScratch> = RefCell::new(GemmScratch::default());
}

/// Per-thread scratch for the INT8 attention core
/// (`model::layers::attn_forward_seg` under [`AttnPrecision::Int8`]):
/// token-major i8 Q/K, d-major i8 V, one quantized probability row, the
/// per-token scale vectors and the score matrix — pooled alongside the
/// GEMM scratch so a batched serve step quantizes attention without
/// per-head heap allocation. Same take/put discipline as the rest of the
/// pool (pop on empty allocates; re-entrancy safe).
#[derive(Default)]
pub(crate) struct AttnScratch {
    /// Token-major i8 queries: `qq[t*dh + i]`.
    pub qq: Vec<i8>,
    /// Token-major i8 keys: `qk[u*dh + i]`.
    pub qk: Vec<i8>,
    /// d-major i8 values: `qv[i*seg + u]` (contiguous per feature row for
    /// the context GEMM's inner dot).
    pub qv: Vec<i8>,
    /// One quantized probability row of the context GEMM.
    pub qr: Vec<i8>,
    /// Per-token symmetric scales for Q / K / V columns.
    pub sq: Vec<f32>,
    pub sk: Vec<f32>,
    pub sv: Vec<f32>,
    /// Transient inverse-scale vector, reused by each quantize stage.
    pub inv: Vec<f32>,
    /// One f32 probability row with the V scales folded in, pre-quantize.
    pub pr: Vec<f32>,
    /// Per-segment score matrix (reused across heads/segments).
    pub scores: Matrix,
}

pub(crate) fn take_scratch_attn() -> AttnScratch {
    GEMM_SCRATCH.with(|s| s.borrow_mut().attn.pop()).unwrap_or_default()
}

pub(crate) fn put_scratch_attn(a: AttnScratch) {
    GEMM_SCRATCH.with(|s| s.borrow_mut().attn.push(a));
}

/// Pop/push one transform-domain z buffer — the Haar butterfly writes
/// into a pooled buffer (`transform::HaarTransform::transform_act_into`)
/// before quantizing straight into the pooled [`ActI8`], so the
/// transform-packed serving path is allocation-free per layer too.
pub(crate) fn take_scratch_z() -> Vec<f32> {
    GEMM_SCRATCH.with(|s| s.borrow_mut().zbufs.pop()).unwrap_or_default()
}

pub(crate) fn put_scratch_z(z: Vec<f32>) {
    GEMM_SCRATCH.with(|s| s.borrow_mut().zbufs.push(z));
}

/// Sum of `x[base + b]` over the set bits b of one (already masked) sign
/// word — the per-word body the wide f32 lane unrolls four copies of.
#[inline(always)]
fn word_set_sum(mut bits: u64, base: usize, x: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    while bits != 0 {
        let b = bits.trailing_zeros() as usize;
        acc += x[base + b];
        bits &= bits - 1;
    }
    acc
}

/// Add one (already masked) sign word's per-plane popcounts into the 8
/// counters: `cnt[b] += popcnt(sbits ∧ planes[b])`. The per-word body of
/// the portable wide lane; exact by construction (popcounts are integer).
#[inline(always)]
fn slice_counts(cnt: &mut [u32; 8], sbits: u64, planes: &[u64]) {
    if sbits == 0 {
        return;
    }
    for (c, p) in cnt.iter_mut().zip(planes) {
        *c += (sbits & p).count_ones();
    }
}

/// Fold the 8 per-plane popcounts into the signed i8 set-sum
/// Σ_{b=0..6} 2^b·cnt[b] − 128·cnt[7], widened to i64 for the combine
/// (group sums are far below i32 range; the widening only guards the
/// intermediate products).
#[inline(always)]
fn combine_counts(cnt: &[u32; 8]) -> i32 {
    let pos = cnt[0] as i64
        + 2 * cnt[1] as i64
        + 4 * cnt[2] as i64
        + 8 * cnt[3] as i64
        + 16 * cnt[4] as i64
        + 32 * cnt[5] as i64
        + 64 * cnt[6] as i64;
    (pos - 128 * cnt[7] as i64) as i32
}

/// AVX2 lane of the bit-sliced popcount kernel. Free functions (not
/// methods) so the `#[target_feature]` boundary is explicit; compiled
/// only on x86_64 and *called* only when [`avx2_available`] reported
/// support at runtime.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Per-64-bit-lane popcount via the Mula nibble-LUT algorithm: split
    /// each byte into nibbles, table-lookup their popcounts with
    /// `_mm256_shuffle_epi8`, then `_mm256_sad_epu8` horizontally sums
    /// the 8 byte-counts of each u64 lane.
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_epi64(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3,
            2, 3, 3, 4,
        );
        let low_mask = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16(v, 4), low_mask);
        let cnt8 = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt8, _mm256_setzero_si256())
    }

    /// Bit-sliced i8 set-sum over columns [s, e) of the row at `wbase`:
    /// all 8 planes of each sign word are ANDed and popcounted in two
    /// 256-bit ops (planes 0–3 and 4–7), accumulating per-plane counts in
    /// u64 lanes; the final combine applies the plane weights exactly as
    /// the portable lanes do, so the result is bit-identical to them.
    ///
    /// # Safety
    /// Requires AVX2 (callers gate on [`super::avx2_available`]).
    /// `slices` must hold 8 plane words per sign word of the span, as
    /// built by `quantize_act_with_scale_into`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn set_sum_sliced(
        signs: &[u64],
        wbase: usize,
        s: usize,
        e: usize,
        slices: &[u64],
    ) -> i32 {
        let w0 = s / 64;
        let w1 = (e - 1) / 64;
        let mut acc_lo = _mm256_setzero_si256(); // planes 0..=3 counts, u64 lanes
        let mut acc_hi = _mm256_setzero_si256(); // planes 4..=7
        for wi in w0..=w1 {
            let mut sbits = signs[wbase + wi];
            if wi == w0 {
                sbits &= u64::MAX << (s % 64);
            }
            if wi == w1 {
                let top = e - wi * 64;
                if top < 64 {
                    sbits &= (1u64 << top) - 1;
                }
            }
            if sbits == 0 {
                continue;
            }
            let sv = _mm256_set1_epi64x(sbits as i64);
            let base = slices.as_ptr().add(wi * 8);
            let plo = _mm256_loadu_si256(base as *const __m256i);
            let phi = _mm256_loadu_si256(base.add(4) as *const __m256i);
            acc_lo = _mm256_add_epi64(acc_lo, popcnt_epi64(_mm256_and_si256(sv, plo)));
            acc_hi = _mm256_add_epi64(acc_hi, popcnt_epi64(_mm256_and_si256(sv, phi)));
        }
        let mut cnt = [0u64; 8];
        _mm256_storeu_si256(cnt.as_mut_ptr() as *mut __m256i, acc_lo);
        _mm256_storeu_si256(cnt.as_mut_ptr().add(4) as *mut __m256i, acc_hi);
        let pos = cnt[0] as i64
            + 2 * cnt[1] as i64
            + 4 * cnt[2] as i64
            + 8 * cnt[3] as i64
            + 16 * cnt[4] as i64
            + 32 * cnt[5] as i64
            + 64 * cnt[6] as i64;
        (pos - 128 * cnt[7] as i64) as i32
    }
}

/// Take/put access to the scratch transpose buffer for sibling modules
/// (the transform-domain path transposes its own activations before
/// feeding the token-major GEMM entries).
pub(crate) fn take_scratch_xt() -> Matrix {
    GEMM_SCRATCH.with(|s| std::mem::take(&mut s.borrow_mut().xt))
}

pub(crate) fn put_scratch_xt(xt: Matrix) {
    GEMM_SCRATCH.with(|s| s.borrow_mut().xt = xt);
}

/// Pop/push one quantized-token buffer from the shared pool — the
/// single-token (GEMV) serving path reuses ActI8 allocations across
/// layers through these, like the GEMM entries do through the pool
/// directly. Pop on an empty pool just allocates (re-entrancy safe).
pub(crate) fn take_scratch_act() -> ActI8 {
    GEMM_SCRATCH.with(|s| s.borrow_mut().acts.pop()).unwrap_or_default()
}

pub(crate) fn put_scratch_act(act: ActI8) {
    GEMM_SCRATCH.with(|s| s.borrow_mut().acts.push(act));
}

/// A packed 1-bit matrix: for each row, `cols` sign bits in u64 words and
/// one (α, μ) pair per group of `group_size` consecutive columns, plus an
/// optional residual bitplane chain (order-K packing) sharing the same
/// group layout.
#[derive(Clone, Debug)]
pub struct PackedBits {
    pub rows: usize,
    pub cols: usize,
    pub group_size: usize,
    words_per_row: usize,
    groups_per_row: usize,
    /// Row-major sign words; bit j of word (r, j/64) set ⇒ sign +1.
    signs: Vec<u64>,
    /// Row-major per-group scales α.
    alpha: Vec<f32>,
    /// Row-major per-group means μ.
    mu: Vec<f32>,
    /// Next residual bitplane (same rows/cols/group layout), if any.
    residual: Option<Box<PackedBits>>,
}

impl PackedBits {
    /// Pack a dense matrix: each group of `group_size` columns in each row
    /// is binarized as μ + α·sign(w − μ) and the signs stored packed.
    pub fn pack(w: &Matrix, group_size: usize) -> Self {
        let group_size = group_size.max(1);
        let words_per_row = w.cols.div_ceil(64);
        let groups_per_row = w.cols.div_ceil(group_size);
        let mut signs = vec![0u64; w.rows * words_per_row];
        let mut alpha = vec![0f32; w.rows * groups_per_row];
        let mut mu = vec![0f32; w.rows * groups_per_row];
        for r in 0..w.rows {
            let row = w.row(r);
            for g in 0..groups_per_row {
                let s = g * group_size;
                let e = (s + group_size).min(w.cols);
                let seg = &row[s..e];
                let m = seg.iter().sum::<f32>() / seg.len() as f32;
                let a = seg.iter().map(|&v| (v - m).abs()).sum::<f32>() / seg.len() as f32;
                mu[r * groups_per_row + g] = m;
                alpha[r * groups_per_row + g] = a;
                for (k, &v) in seg.iter().enumerate() {
                    if v >= m {
                        let j = s + k;
                        signs[r * words_per_row + j / 64] |= 1u64 << (j % 64);
                    }
                }
            }
        }
        PackedBits {
            rows: w.rows,
            cols: w.cols,
            group_size,
            words_per_row,
            groups_per_row,
            signs,
            alpha,
            mu,
            residual: None,
        }
    }

    /// Order-K packing: binarize, then repeatedly binarize the remaining
    /// residual into further bitplanes until either `max_order` planes are
    /// used or the dequantization captures `w` to within `rel_tol` relative
    /// Frobenius energy. Order 1 with `rel_tol = 0` reproduces [`pack`].
    pub fn pack_residual(w: &Matrix, group_size: usize, max_order: usize, rel_tol: f64) -> Self {
        let denom = w.frob_norm_sq().max(1e-30);
        let mut planes: Vec<PackedBits> = Vec::new();
        let mut resid = w.clone();
        for _ in 0..max_order.max(1) {
            let p = PackedBits::pack(&resid, group_size);
            resid = resid.sub(&p.dequantize_plane());
            planes.push(p);
            if resid.frob_norm_sq() / denom <= rel_tol {
                break;
            }
        }
        Self::chain_planes(planes)
    }

    /// Deploy-default packing of a method's dense reconstruction (see the
    /// `DEPLOY_*` constants): the form PTQ methods commit to the
    /// [`crate::model::params::ParamStore`] for bit-true serving.
    pub fn pack_deploy(w: &Matrix) -> Self {
        Self::pack_residual(w, DEPLOY_GROUP_SIZE, DEPLOY_MAX_ORDER, DEPLOY_REL_TOL)
    }

    /// Link a vector of planes (first = base) into a residual chain.
    fn chain_planes(mut planes: Vec<PackedBits>) -> Self {
        assert!(!planes.is_empty());
        let mut chain: Option<PackedBits> = None;
        while let Some(mut p) = planes.pop() {
            p.residual = chain.take().map(Box::new);
            chain = Some(p);
        }
        chain.unwrap()
    }

    /// Number of bitplanes (1 for a plain [`pack`]).
    pub fn order(&self) -> usize {
        1 + self.residual.as_deref().map_or(0, |r| r.order())
    }

    /// Dequantize one plane (no residual chain).
    fn dequantize_plane(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row = out.row_mut(r);
            for j in 0..self.cols {
                let g = j / self.group_size;
                let a = self.alpha[r * self.groups_per_row + g];
                let m = self.mu[r * self.groups_per_row + g];
                let bit = (self.signs[r * self.words_per_row + j / 64] >> (j % 64)) & 1;
                row[j] = m + if bit == 1 { a } else { -a };
            }
        }
        out
    }

    /// Dequantize to a dense matrix: the sum of every bitplane's
    /// reconstruction (the dense twin of the packed execution path).
    pub fn dequantize(&self) -> Matrix {
        let mut out = self.dequantize_plane();
        let mut plane = self.residual.as_deref();
        while let Some(p) = plane {
            out.add_assign(&p.dequantize_plane());
            plane = p.residual.as_deref();
        }
        out
    }

    /// Sum of `x` over the *set* sign bits of row-word-base `wbase` within
    /// columns [s, e): the wide-lane inner loop. Boundary masks are
    /// applied only on the first/last word of the span; interior words run
    /// unmasked, 4 per step, with four independent per-word accumulators
    /// (`word_set_sum`) combined pairwise — the popcount/extraction chains
    /// of neighboring words overlap instead of serializing on one f32 add
    /// chain. This reorders the f32 summation relative to the PR-5 serial
    /// loop, which is fine: every f32 entry point (GEMV, GEMM, serial,
    /// parallel) shares THIS one function, so their mutual bit-identity
    /// contracts are untouched, and the dense-twin comparisons are
    /// tolerance-based.
    #[inline]
    fn set_sum(&self, wbase: usize, s: usize, e: usize, x: &[f32]) -> f32 {
        debug_assert!(s < e);
        let w0 = s / 64;
        let w1 = (e - 1) / 64;
        let lo_mask = u64::MAX << (s % 64);
        if w0 == w1 {
            let mut bits = self.signs[wbase + w0] & lo_mask;
            let top = e - w0 * 64; // 1..=64 valid bits in the last word
            if top < 64 {
                bits &= (1u64 << top) - 1;
            }
            return word_set_sum(bits, w0 * 64, x);
        }
        let mut acc = word_set_sum(self.signs[wbase + w0] & lo_mask, w0 * 64, x);
        let mut wi = w0 + 1;
        while wi + 4 <= w1 {
            let a0 = word_set_sum(self.signs[wbase + wi], wi * 64, x);
            let a1 = word_set_sum(self.signs[wbase + wi + 1], (wi + 1) * 64, x);
            let a2 = word_set_sum(self.signs[wbase + wi + 2], (wi + 2) * 64, x);
            let a3 = word_set_sum(self.signs[wbase + wi + 3], (wi + 3) * 64, x);
            acc += (a0 + a1) + (a2 + a3);
            wi += 4;
        }
        while wi < w1 {
            acc += word_set_sum(self.signs[wbase + wi], wi * 64, x);
            wi += 1;
        }
        let top = e - w1 * 64; // 1..=64 valid bits in the last word
        let bits = if top < 64 {
            self.signs[wbase + w1] & ((1u64 << top) - 1)
        } else {
            self.signs[wbase + w1]
        };
        acc + word_set_sum(bits, w1 * 64, x)
    }

    /// One row's full GEMV dot (all bitplanes, plane contributions added
    /// in chain order — the accumulation order every f32 entry point
    /// shares, which is what keeps serial/parallel and GEMV/GEMM outputs
    /// bit-identical).
    #[inline]
    fn row_dot(&self, r: usize, x: &[f32], group_sums: &[f32]) -> f32 {
        let mut out = 0.0f32;
        let mut plane = Some(self);
        while let Some(p) = plane {
            let wbase = r * p.words_per_row;
            let gbase = r * p.groups_per_row;
            let mut acc = 0.0f32;
            for g in 0..p.groups_per_row {
                let s = g * p.group_size;
                let e = (s + p.group_size).min(p.cols);
                let set = p.set_sum(wbase, s, e, x);
                let gsum = group_sums[g];
                acc += p.mu[gbase + g] * gsum + p.alpha[gbase + g] * (2.0 * set - gsum);
            }
            out += acc;
            plane = p.residual.as_deref();
        }
        out
    }

    /// Packed GEMV: y = Ŵ x without materializing Ŵ (all bitplanes).
    /// Serial form — [`Self::matvec_mt`] fans rows out over the pool.
    pub fn matvec(&self, x: &[f32], group_sums: &[f32], y: &mut [f32]) {
        self.matvec_mt(x, group_sums, y, 1);
    }

    /// Row-parallel packed GEMV: rows are distributed over the persistent
    /// pool when the layer is large enough ([`GEMV_PAR_MIN`]); below the
    /// threshold (or at `threads == 1`) the serial loop runs. Each row's
    /// value is computed by the same [`Self::row_dot`] either way, so the
    /// output is bit-identical at every thread count.
    pub fn matvec_mt(&self, x: &[f32], group_sums: &[f32], y: &mut [f32], threads: usize) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        assert_eq!(group_sums.len(), self.groups_per_row);
        self.for_each_y_par(y, threads, |r| self.row_dot(r, x, group_sums));
    }

    /// Row-parallel driver for the single-token GEMVs: serial below the
    /// [`GEMV_PAR_MIN`] work threshold, else contiguous row chunks over
    /// the pool. The GEMV sibling of [`Self::for_each_row_par`] — these
    /// two drivers are the ONLY places the disjoint-row unsafe write
    /// lives, shared by every f32/i8 entry point so the threshold and
    /// safety argument cannot diverge.
    fn for_each_y_par<F>(&self, y: &mut [f32], threads: usize, row_fn: F)
    where
        F: Fn(usize) -> f32 + Sync,
    {
        let work = self.rows as f64 * self.cols as f64 * self.order() as f64;
        if threads <= 1 || work < GEMV_PAR_MIN {
            for (r, slot) in y.iter_mut().enumerate() {
                *slot = row_fn(r);
            }
        } else {
            let chunks = (threads * 4).min(self.rows);
            let per = self.rows.div_ceil(chunks);
            let yptr = SendPtr(y.as_mut_ptr());
            parallel_for(chunks, threads, |c| {
                let yptr = &yptr;
                let r0 = c * per;
                let r1 = ((c + 1) * per).min(self.rows);
                for r in r0..r1 {
                    // SAFETY: chunks cover disjoint row ranges of y.
                    unsafe { *yptr.0.add(r) = row_fn(r) };
                }
            });
        }
    }

    /// Allocating GEMV convenience — the form the
    /// [`crate::model::layers::linear_vec`] dispatch calls. Computes the
    /// group sums itself; callers that already hold them (or sweep many
    /// layers over one token) should pass them via
    /// [`Self::matvec_owned_with`] instead of paying the pass again.
    pub fn matvec_owned(&self, x: &[f32]) -> Vec<f32> {
        self.matvec_owned_with(x, None)
    }

    /// [`Self::matvec_owned`] with an optional precomputed group-sum
    /// slice: `Some(sums)` skips the activation sweep entirely (the hot
    /// loops' form — the W1A8 path analogously fuses its sums into
    /// [`Self::quantize_act`]); `None` computes them here. The two entry
    /// points are pinned identical by a regression test.
    pub fn matvec_owned_with(&self, x: &[f32], group_sums: Option<&[f32]>) -> Vec<f32> {
        self.matvec_owned_mt(x, group_sums, default_threads())
    }

    /// [`Self::matvec_owned_with`] with an explicit thread budget — the
    /// form the `model::layers` dispatch calls so a pinned `--threads`
    /// budget reaches the GEMV fan-out.
    pub fn matvec_owned_mt(
        &self,
        x: &[f32],
        group_sums: Option<&[f32]>,
        threads: usize,
    ) -> Vec<f32> {
        let mut y = vec![0.0f32; self.rows];
        match group_sums {
            Some(gs) => self.matvec_mt(x, gs, &mut y, threads),
            None => {
                let gs = self.group_sums(x);
                self.matvec_mt(x, &gs, &mut y, threads);
            }
        }
        y
    }

    /// Quantize one activation token for this layer's group layout: a
    /// scale pass (max|x|), then ONE fused pass that quantizes each
    /// group's slice, accumulates its i32 sum AND builds the 8 column
    /// bit-slices — the i8 twin of [`Self::group_sums`], sharing a single
    /// sweep over x; the slices amortize over every row and residual
    /// plane of the GEMV/GEMM that consumes them.
    pub fn quantize_act(&self, x: &[f32]) -> ActI8 {
        self.quantize_act_with_scale(x, crate::tensor::ops::act_scale_i8(x))
    }

    /// [`Self::quantize_act`] with the symmetric token scale already in
    /// hand — used by the transform-domain serving path (max|z| computed
    /// inside the sweep that builds z) and by the calibrated-static-scale
    /// mode ([`ActScaleMode::Static`]), where the max sweep is skipped
    /// entirely. With `scale == act_scale_i8(x)` the result is bit-equal
    /// to [`Self::quantize_act`] (max is order-independent in f32); with
    /// a calibrated scale, out-of-range values saturate at ±127 — the
    /// intended static-scale behavior.
    pub fn quantize_act_with_scale(&self, x: &[f32], scale: f32) -> ActI8 {
        let mut act = ActI8::default();
        self.quantize_act_with_scale_into(x, scale, &mut act);
        act
    }

    /// In-place form of [`Self::quantize_act_with_scale`]: reuses the
    /// caller's buffers (the GEMM scratch pool feeds quantized tokens
    /// through here so coalesced server batches stop re-allocating per
    /// layer). One fused pass builds q, the per-group i32 sums and the
    /// column bit-slices together.
    pub fn quantize_act_with_scale_into(&self, x: &[f32], scale: f32, act: &mut ActI8) {
        assert_eq!(x.len(), self.cols);
        act.scale = scale;
        // q and group_sums are fully overwritten by the fused loop below
        // (groups tile every column), so resize WITHOUT the clear-first
        // memset; slices accumulates with |= and genuinely needs zeroing.
        act.q.resize(self.cols, 0);
        act.group_sums.resize(self.groups_per_row, 0);
        act.slices.clear();
        act.slices.resize(self.words_per_row * 8, 0);
        if scale <= 0.0 {
            // This path skips the loop, so zero the reused buffers here.
            act.q.iter_mut().for_each(|v| *v = 0);
            act.group_sums.iter_mut().for_each(|v| *v = 0);
            return;
        }
        let inv = 1.0 / scale;
        for g in 0..self.groups_per_row {
            let s = g * self.group_size;
            let e = (s + self.group_size).min(self.cols);
            let mut acc = 0i32;
            for j in s..e {
                let v = crate::tensor::ops::quantize_i8(x[j], inv);
                act.q[j] = v;
                acc += v as i32;
                // Spread the byte's bits over the word's 8 planes.
                let u = v as u8 as u64;
                let base = (j / 64) * 8;
                let bit = (j % 64) as u32;
                for (b, plane) in act.slices[base..base + 8].iter_mut().enumerate() {
                    *plane |= ((u >> b) & 1) << bit;
                }
            }
            act.group_sums[g] = acc;
        }
    }

    /// Bit-sliced set-bit sum: Σ q[j] over the set sign bits of
    /// row-word-base `wbase` within columns [s, e), computed from the
    /// token's column bit-planes as
    ///   Σ_{b=0..6} 2^b·popcnt(S ∧ Q_b) − 128·popcnt(S ∧ Q_7)
    /// (two's-complement plane weights: bit 7 of an i8 carries −128).
    /// 8 AND+POPCNT per 64 columns, branchless — no serial dependent
    /// chain on `trailing_zeros` — and integer-exact, so the result is
    /// bit-identical to the extraction loop [`Self::set_sum_i8`].
    /// Accumulation stays in i32: Σ2^b·popcnt ≤ 127·2^24 < i32::MAX at
    /// the serialization dimension cap.
    #[inline]
    fn set_sum_i8_sliced(&self, wbase: usize, s: usize, e: usize, slices: &[u64]) -> i32 {
        debug_assert!(s < e);
        let mut pos = 0i32;
        let mut hi = 0i32;
        let w0 = s / 64;
        let w1 = (e - 1) / 64;
        for wi in w0..=w1 {
            let mut sbits = self.signs[wbase + wi];
            if wi == w0 {
                sbits &= u64::MAX << (s % 64);
            }
            if wi == w1 {
                let top = e - wi * 64;
                if top < 64 {
                    sbits &= (1u64 << top) - 1;
                }
            }
            if sbits == 0 {
                continue;
            }
            let p = &slices[wi * 8..wi * 8 + 8];
            pos += (sbits & p[0]).count_ones() as i32
                + 2 * (sbits & p[1]).count_ones() as i32
                + 4 * (sbits & p[2]).count_ones() as i32
                + 8 * (sbits & p[3]).count_ones() as i32
                + 16 * (sbits & p[4]).count_ones() as i32
                + 32 * (sbits & p[5]).count_ones() as i32
                + 64 * (sbits & p[6]).count_ones() as i32;
            hi += (sbits & p[7]).count_ones() as i32;
        }
        // The final value Σq fits i32 (|q| ≤ 127, ≤ 2^24 columns), but
        // the intermediate 128·hi alone can reach exactly 2^31 when a
        // single group spans the full dimension cap with every negative
        // bit set — widen just this combination.
        (pos as i64 - 128 * hi as i64) as i32
    }

    /// Portable wide lane of the bit-sliced kernel: boundary words are
    /// masked once up front, then the interior runs 4 sign words per
    /// step against their 32 contiguous plane words, accumulating all 8
    /// plane popcounts in independent `u32` counters — four AND+POPCNT
    /// chains in flight per plane instead of one. Integer-exact, so
    /// bit-identical to [`Self::set_sum_i8_sliced`] by construction
    /// (counter headroom: ≤ 2^24 columns ⇒ each count ≤ 2^24 < u32 max;
    /// the weighted combine widens to i64 as the scalar lane does).
    #[inline]
    fn set_sum_i8_sliced_wide4(&self, wbase: usize, s: usize, e: usize, slices: &[u64]) -> i32 {
        debug_assert!(s < e);
        let w0 = s / 64;
        let w1 = (e - 1) / 64;
        let lo_mask = u64::MAX << (s % 64);
        let mut cnt = [0u32; 8];
        if w0 == w1 {
            let mut sbits = self.signs[wbase + w0] & lo_mask;
            let top = e - w0 * 64;
            if top < 64 {
                sbits &= (1u64 << top) - 1;
            }
            slice_counts(&mut cnt, sbits, &slices[w0 * 8..w0 * 8 + 8]);
            return combine_counts(&cnt);
        }
        slice_counts(&mut cnt, self.signs[wbase + w0] & lo_mask, &slices[w0 * 8..w0 * 8 + 8]);
        let mut wi = w0 + 1;
        while wi + 4 <= w1 {
            let p = &slices[wi * 8..wi * 8 + 32];
            let s0 = self.signs[wbase + wi];
            let s1 = self.signs[wbase + wi + 1];
            let s2 = self.signs[wbase + wi + 2];
            let s3 = self.signs[wbase + wi + 3];
            for (b, c) in cnt.iter_mut().enumerate() {
                *c += (s0 & p[b]).count_ones()
                    + (s1 & p[b + 8]).count_ones()
                    + (s2 & p[b + 16]).count_ones()
                    + (s3 & p[b + 24]).count_ones();
            }
            wi += 4;
        }
        while wi < w1 {
            slice_counts(&mut cnt, self.signs[wbase + wi], &slices[wi * 8..wi * 8 + 8]);
            wi += 1;
        }
        let top = e - w1 * 64;
        let tail = if top < 64 {
            self.signs[wbase + w1] & ((1u64 << top) - 1)
        } else {
            self.signs[wbase + w1]
        };
        slice_counts(&mut cnt, tail, &slices[w1 * 8..w1 * 8 + 8]);
        combine_counts(&cnt)
    }

    /// AVX2 lane wrapper — only reachable through
    /// [`Self::set_sum_i8_sliced_lane`] after runtime detection.
    #[cfg(target_arch = "x86_64")]
    #[inline]
    fn set_sum_i8_sliced_avx2(&self, wbase: usize, s: usize, e: usize, slices: &[u64]) -> i32 {
        debug_assert!(s < e);
        // SAFETY: callers only select `SimdLane::Avx2` when
        // `avx2_available()` reported CPU support (`SimdLane::active` /
        // `SimdLane::available`), and `slices` is a full 8-planes-per-word
        // buffer built by `quantize_act_with_scale_into`.
        unsafe { avx2::set_sum_sliced(&self.signs, wbase, s, e, slices) }
    }

    /// Lane dispatcher for the bit-sliced set-sum: all lanes compute the
    /// identical integer result, so this is purely a speed choice. The
    /// hot path passes [`SimdLane::active`] (resolved once per call tree,
    /// not per group); the forced-lane entries pass an explicit lane so
    /// the test walls pin every lane against the extraction reference.
    /// `Avx2` on a non-x86_64 build (or an undetected CPU — guarded by
    /// the callers) degrades to the portable wide lane.
    #[inline]
    fn set_sum_i8_sliced_lane(
        &self,
        wbase: usize,
        s: usize,
        e: usize,
        slices: &[u64],
        lane: SimdLane,
    ) -> i32 {
        match lane {
            SimdLane::Scalar => self.set_sum_i8_sliced(wbase, s, e, slices),
            SimdLane::Wide4 => self.set_sum_i8_sliced_wide4(wbase, s, e, slices),
            #[cfg(target_arch = "x86_64")]
            SimdLane::Avx2 => self.set_sum_i8_sliced_avx2(wbase, s, e, slices),
            #[cfg(not(target_arch = "x86_64"))]
            SimdLane::Avx2 => self.set_sum_i8_sliced_wide4(wbase, s, e, slices),
        }
    }

    /// i8 twin of [`Self::set_sum`]: sum of q over the *set* sign bits of
    /// row-word-base `wbase` within columns [s, e), accumulated in i32
    /// (|q| ≤ 127 with cols capped at 2^24 keeps any group sum inside
    /// i32 range). One activation is consumed per `trailing_zeros` — a
    /// serial dependent chain the bit-sliced kernel replaces on the hot
    /// path; kept as the independent reference implementation for parity
    /// tests and the extraction-vs-sliced bench.
    #[inline]
    fn set_sum_i8(&self, wbase: usize, s: usize, e: usize, q: &[i8]) -> i32 {
        let mut acc = 0i32;
        let mut j = s;
        while j < e {
            let wi = j / 64;
            let upto = e.min((wi + 1) * 64);
            let lo = j % 64;
            let span = upto - j;
            let mask = if span == 64 { u64::MAX } else { ((1u64 << span) - 1) << lo };
            let mut bits = self.signs[wbase + wi] & mask;
            let base = wi * 64;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                acc += q[base + b] as i32;
                bits &= bits - 1;
            }
            j = upto;
        }
        acc
    }

    /// One (row, token) accumulation of ONE plane in the integer kernel:
    /// per group, the two integer sums (Σ q over the group, Σ q over set
    /// bits — the latter via the bit-sliced popcount kernel) are rescaled
    /// ONCE by the token scale,
    ///   s_tok · (μ_g Σq + α_g (2 Σ_set q − Σq)),
    /// so the inner loop stays pure integer and the f32 work is two
    /// multiply-adds per group. Shared verbatim by [`Self::matvec_i8`]
    /// and [`Self::matmul_i8`], which makes the two entry points
    /// bit-identical per token — the property the batched-serve parity
    /// tests pin. Falls back to the extraction loop for an `ActI8` built
    /// without slices (never the case on in-tree paths).
    #[inline]
    fn row_acc_i8(&self, wbase: usize, gbase: usize, act: &ActI8, lane: SimdLane) -> f32 {
        let sliced = act.slices.len() == self.words_per_row * 8;
        let mut acc = 0.0f32;
        for g in 0..self.groups_per_row {
            let s = g * self.group_size;
            let e = (s + self.group_size).min(self.cols);
            let set = if sliced {
                self.set_sum_i8_sliced_lane(wbase, s, e, &act.slices, lane)
            } else {
                self.set_sum_i8(wbase, s, e, &act.q)
            };
            let gsum = act.group_sums[g];
            // 2·set − gsum in i64: a single full-width group of extreme
            // activations can push 2·set past i32::MAX.
            let signed = (2 * set as i64 - gsum as i64) as f32;
            acc += act.scale * (self.mu[gbase + g] * gsum as f32 + self.alpha[gbase + g] * signed);
        }
        acc
    }

    /// Reference (row, token) accumulation using the `trailing_zeros`
    /// extraction loop — the PR-3 kernel, kept (like
    /// [`Self::matvec_per_bit`]) as an independent implementation for the
    /// bit-exactness parity wall and the extraction-vs-sliced bench.
    #[inline]
    fn row_acc_i8_extract(&self, wbase: usize, gbase: usize, act: &ActI8) -> f32 {
        let mut acc = 0.0f32;
        for g in 0..self.groups_per_row {
            let s = g * self.group_size;
            let e = (s + self.group_size).min(self.cols);
            let set = self.set_sum_i8(wbase, s, e, &act.q);
            let gsum = act.group_sums[g];
            let signed = (2 * set as i64 - gsum as i64) as f32;
            acc += act.scale * (self.mu[gbase + g] * gsum as f32 + self.alpha[gbase + g] * signed);
        }
        acc
    }

    /// One row's full W1A8 dot over all bitplanes (plane contributions in
    /// chain order — shared accumulation order with the GEMM).
    #[inline]
    fn row_dot_i8(&self, r: usize, act: &ActI8, lane: SimdLane) -> f32 {
        let mut out = 0.0f32;
        let mut plane = Some(self);
        while let Some(p) = plane {
            out += p.row_acc_i8(r * p.words_per_row, r * p.groups_per_row, act, lane);
            plane = p.residual.as_deref();
        }
        out
    }

    /// W1A8 packed GEMV: y = Ŵ x̂ with x̂ = s_tok · q, over all bitplanes,
    /// bit-sliced popcount inner loop, i32 accumulation inside every
    /// group. Serial form — [`Self::matvec_i8_mt`] fans rows out.
    pub fn matvec_i8(&self, act: &ActI8, y: &mut [f32]) {
        self.matvec_i8_mt(act, y, 1);
    }

    /// Row-parallel W1A8 GEMV (same threshold/parity contract as
    /// [`Self::matvec_mt`]) on the auto-selected [`SimdLane::active`].
    pub fn matvec_i8_mt(&self, act: &ActI8, y: &mut [f32], threads: usize) {
        self.matvec_i8_lane(act, y, threads, SimdLane::active());
    }

    /// Forced-lane W1A8 GEMV: identical to [`Self::matvec_i8_mt`] except
    /// the sliced inner loop runs the EXPLICIT `lane`. The parity walls
    /// call this for every [`SimdLane::available`] lane so each one is
    /// pinned bit-identical to [`Self::matvec_i8_extract`] regardless of
    /// which lane the host auto-selects or what `RUSTFLAGS` built the
    /// binary with.
    pub fn matvec_i8_lane(&self, act: &ActI8, y: &mut [f32], threads: usize, lane: SimdLane) {
        assert_eq!(act.q.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        assert_eq!(act.group_sums.len(), self.groups_per_row);
        self.for_each_y_par(y, threads, |r| self.row_dot_i8(r, act, lane));
    }

    /// Reference W1A8 GEMV on the extraction kernel (bench/test twin of
    /// [`Self::matvec_i8`], same role as [`Self::matvec_per_bit`] for the
    /// f32 path). Bit-identical to the sliced kernel by construction —
    /// pinned by unit and property tests.
    pub fn matvec_i8_extract(&self, act: &ActI8, y: &mut [f32]) {
        assert_eq!(act.q.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        assert_eq!(act.group_sums.len(), self.groups_per_row);
        for (r, slot) in y.iter_mut().enumerate() {
            let mut out = 0.0f32;
            let mut plane = Some(self);
            while let Some(p) = plane {
                out += p.row_acc_i8_extract(r * p.words_per_row, r * p.groups_per_row, act);
                plane = p.residual.as_deref();
            }
            *slot = out;
        }
    }

    /// Allocating W1A8 GEMV (quantizes the token itself) — the form the
    /// [`crate::model::layers::linear_vec`] dispatch calls under
    /// [`ActPrecision::Int8`].
    pub fn matvec_i8_owned(&self, x: &[f32]) -> Vec<f32> {
        self.matvec_i8_owned_with_scale(x, None)
    }

    /// [`Self::matvec_i8_owned`] with an optional calibrated static scale
    /// ([`ActScaleMode::Static`]): `Some(s)` skips the max|x| sweep and
    /// runs the single fused quantize+group-sum+bit-slice pass; `None`
    /// computes the per-token scale first.
    pub fn matvec_i8_owned_with_scale(&self, x: &[f32], scale: Option<f32>) -> Vec<f32> {
        self.matvec_i8_owned_mt(x, scale, default_threads())
    }

    /// [`Self::matvec_i8_owned_with_scale`] with an explicit thread
    /// budget (the dispatch form — see [`Self::matvec_owned_mt`]). The
    /// quantized-token buffers come from the shared scratch pool, so
    /// sequential serving sweeping many layers per token reuses them
    /// instead of allocating three Vecs per layer.
    pub fn matvec_i8_owned_mt(&self, x: &[f32], scale: Option<f32>, threads: usize) -> Vec<f32> {
        let mut act = take_scratch_act();
        let s = scale.unwrap_or_else(|| crate::tensor::ops::act_scale_i8(x));
        self.quantize_act_with_scale_into(x, s, &mut act);
        let mut y = vec![0.0f32; self.rows];
        self.matvec_i8_mt(&act, &mut y, threads);
        put_scratch_act(act);
        y
    }

    /// One row of the W1A8 packed GEMM (i8 twin of [`Self::row_tokens`]):
    /// plane-outer, token-inner, with the same per-(row, token)
    /// accumulation order as [`Self::matvec_i8`].
    fn row_tokens_i8(&self, r: usize, acts: &[ActI8], orow: &mut [f32], lane: SimdLane) {
        orow.iter_mut().for_each(|v| *v = 0.0);
        let mut plane = Some(self);
        while let Some(p) = plane {
            let wbase = r * p.words_per_row;
            let gbase = r * p.groups_per_row;
            for (t, slot) in orow.iter_mut().enumerate() {
                *slot += p.row_acc_i8(wbase, gbase, &acts[t], lane);
            }
            plane = p.residual.as_deref();
        }
    }

    /// W1A8 packed multi-token GEMM: Y = Ŵ X̂ (X: cols × n_tokens), each
    /// token quantized to i8 with its own symmetric scale in the same
    /// sweep that builds its per-group sums and bit-slices.
    /// Single-threaded form of [`Self::matmul_i8_mt`].
    pub fn matmul_i8(&self, x: &Matrix) -> Matrix {
        self.matmul_i8_mt(x, 1)
    }

    /// W1A8 packed GEMM with rows distributed over `threads` workers via
    /// [`Self::for_each_row_par`] (same work threshold and disjoint-row
    /// write as [`Self::matmul_mt`]).
    pub fn matmul_i8_mt(&self, x: &Matrix, threads: usize) -> Matrix {
        self.matmul_i8_with_scale(x, threads, None)
    }

    /// [`Self::matmul_i8_mt`] with an optional calibrated static token
    /// scale (`Some(s)` = every token quantized at s, max sweeps skipped —
    /// the [`ActScaleMode::Static`] GEMM). The activation transpose and
    /// the quantized-token pool come from the per-thread scratch, so a
    /// server batch sweeping many layers reuses them instead of
    /// re-allocating per call.
    pub fn matmul_i8_with_scale(&self, x: &Matrix, threads: usize, scale: Option<f32>) -> Matrix {
        assert_eq!(
            x.rows, self.cols,
            "packed i8 matmul shape mismatch: {}x{} @ {}x{}",
            self.rows, self.cols, x.rows, x.cols
        );
        let mut xt = take_scratch_xt();
        x.transpose_into(&mut xt);
        let out = self.matmul_i8_t(&xt, threads, scale);
        put_scratch_xt(xt);
        out
    }

    /// W1A8 GEMM over a TOKEN-MAJOR activation matrix (`xt`: n_tokens ×
    /// cols, one token per row) — the transpose-free entry the
    /// transform-domain path feeds directly.
    pub fn matmul_i8_t(&self, xt: &Matrix, threads: usize, scale: Option<f32>) -> Matrix {
        assert_eq!(xt.cols, self.cols, "token-major i8 matmul dim mismatch");
        // Per-token quantization + fused group sums + bit-slices, reusing
        // the thread's quantized-token pool across calls.
        self.matmul_i8_tokens_with(xt.rows, threads, |t, act| {
            let row = xt.row(t);
            let s = scale.unwrap_or_else(|| crate::tensor::ops::act_scale_i8(row));
            self.quantize_act_with_scale_into(row, s, act);
        })
    }

    /// W1A8 GEMM over tokens produced by a caller-supplied quantizer
    /// (token index → fills the pooled [`ActI8`] in place): the
    /// transform-domain path quantizes straight out of its fused
    /// gather+Haar sweep into the shared scratch pool through this
    /// entry, so batched exact serving reuses quantized-token buffers
    /// across layers exactly like the direct packed path.
    pub fn matmul_i8_tokens_with<Q>(&self, n_tokens: usize, threads: usize, quantize: Q) -> Matrix
    where
        Q: Fn(usize, &mut ActI8),
    {
        let mut acts = GEMM_SCRATCH.with(|s| std::mem::take(&mut s.borrow_mut().acts));
        // Grow-only: a smaller batch must not free the larger batch's
        // buffers (mixed batch sizes would otherwise re-pay the
        // allocations the pool exists to amortize).
        if acts.len() < n_tokens {
            acts.resize_with(n_tokens, ActI8::default);
        }
        for (t, act) in acts[..n_tokens].iter_mut().enumerate() {
            quantize(t, act);
        }
        let out = self.matmul_i8_acts(&acts[..n_tokens], threads);
        GEMM_SCRATCH.with(|s| s.borrow_mut().acts = acts);
        out
    }

    /// W1A8 GEMM over PRE-QUANTIZED tokens: the entry for callers that
    /// already hold each token's [`ActI8`] — the transform-domain path
    /// quantizes straight out of its fused gather+Haar+max sweep and
    /// feeds the acts here, so no activation is ever swept twice.
    pub fn matmul_i8_acts(&self, acts: &[ActI8], threads: usize) -> Matrix {
        self.matmul_i8_acts_lane(acts, threads, SimdLane::active())
    }

    /// Forced-lane form of [`Self::matmul_i8_acts`] — the GEMM sibling of
    /// [`Self::matvec_i8_lane`], used by the lane parity walls and the
    /// wide-lane-vs-scalar bench table.
    pub fn matmul_i8_acts_lane(&self, acts: &[ActI8], threads: usize, lane: SimdLane) -> Matrix {
        for a in acts {
            assert_eq!(a.q.len(), self.cols, "pre-quantized token dim mismatch");
            assert_eq!(a.group_sums.len(), self.groups_per_row);
        }
        let mut out = Matrix::zeros(self.rows, acts.len());
        self.for_each_row_par(&mut out, threads, |r, orow| {
            self.row_tokens_i8(r, acts, orow, lane)
        });
        out
    }

    /// Forced-lane W1A8 GEMM over a column-major activation matrix:
    /// quantizes each token per-token exactly like [`Self::matmul_i8_mt`]
    /// and runs the explicit `lane` — bit-identical to
    /// [`Self::matmul_i8_extract`] on every lane (pinned by proptests).
    pub fn matmul_i8_lane(&self, x: &Matrix, threads: usize, lane: SimdLane) -> Matrix {
        assert_eq!(
            x.rows, self.cols,
            "packed i8 matmul shape mismatch: {}x{} @ {}x{}",
            self.rows, self.cols, x.rows, x.cols
        );
        let mut xt = take_scratch_xt();
        x.transpose_into(&mut xt);
        let n_tokens = xt.rows;
        let mut acts = GEMM_SCRATCH.with(|s| std::mem::take(&mut s.borrow_mut().acts));
        if acts.len() < n_tokens {
            acts.resize_with(n_tokens, ActI8::default);
        }
        for (t, act) in acts[..n_tokens].iter_mut().enumerate() {
            let row = xt.row(t);
            let s = crate::tensor::ops::act_scale_i8(row);
            self.quantize_act_with_scale_into(row, s, act);
        }
        let out = self.matmul_i8_acts_lane(&acts[..n_tokens], threads, lane);
        GEMM_SCRATCH.with(|s| s.borrow_mut().acts = acts);
        put_scratch_xt(xt);
        out
    }

    /// Reference W1A8 GEMM on the extraction kernel (bench/test twin of
    /// [`Self::matmul_i8`]). Single-threaded form of
    /// [`Self::matmul_i8_extract_mt`].
    pub fn matmul_i8_extract(&self, x: &Matrix) -> Matrix {
        self.matmul_i8_extract_mt(x, 1)
    }

    /// Reference-path quantizer: q + per-group sums only, NO bit-slices
    /// — exactly what the pre-slicing kernel built. Keeps the
    /// extraction-vs-sliced bench honest: the reference must not pay
    /// the slicing cost its inner loop never consumes.
    pub fn quantize_act_extract(&self, x: &[f32]) -> ActI8 {
        assert_eq!(x.len(), self.cols);
        let scale = crate::tensor::ops::act_scale_i8(x);
        let mut q = vec![0i8; self.cols];
        let mut group_sums = vec![0i32; self.groups_per_row];
        if scale > 0.0 {
            let inv = 1.0 / scale;
            for (g, gsum) in group_sums.iter_mut().enumerate() {
                let s = g * self.group_size;
                let e = (s + self.group_size).min(self.cols);
                let mut acc = 0i32;
                for j in s..e {
                    let v = crate::tensor::ops::quantize_i8(x[j], inv);
                    q[j] = v;
                    acc += v as i32;
                }
                *gsum = acc;
            }
        }
        ActI8 { q, scale, group_sums, slices: Vec::new() }
    }

    /// Threaded extraction-reference GEMM — same row distribution and
    /// threshold as the sliced kernel, so the extraction-vs-sliced bench
    /// isolates the inner-loop change rather than the threading (tokens
    /// are quantized WITHOUT bit-slices, like the pre-slicing kernel).
    pub fn matmul_i8_extract_mt(&self, x: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.rows, self.cols, "packed i8 matmul shape mismatch");
        let n = x.cols;
        let xt = x.transpose();
        let acts: Vec<ActI8> = (0..n).map(|t| self.quantize_act_extract(xt.row(t))).collect();
        let mut out = Matrix::zeros(self.rows, n);
        self.for_each_row_par(&mut out, threads, |r, orow| {
            orow.iter_mut().for_each(|v| *v = 0.0);
            let mut plane = Some(self);
            while let Some(p) = plane {
                let wbase = r * p.words_per_row;
                let gbase = r * p.groups_per_row;
                for (t, slot) in orow.iter_mut().enumerate() {
                    *slot += p.row_acc_i8_extract(wbase, gbase, &acts[t]);
                }
                plane = p.residual.as_deref();
            }
        });
        out
    }

    /// Reference GEMV processing one sign bit per iteration (the original
    /// kernel: per-bit shift + IEEE sign-bit XOR). Kept for the
    /// word-at-a-time speedup measurement in `benches/perf_micro.rs` and
    /// as an independent implementation for parity tests.
    pub fn matvec_per_bit(&self, x: &[f32], group_sums: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        assert_eq!(group_sums.len(), self.groups_per_row);
        y.iter_mut().for_each(|v| *v = 0.0);
        let mut plane = Some(self);
        while let Some(p) = plane {
            for (r, slot) in y.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                let wbase = r * p.words_per_row;
                let gbase = r * p.groups_per_row;
                for g in 0..p.groups_per_row {
                    let s = g * p.group_size;
                    let e = (s + p.group_size).min(p.cols);
                    let mut signed_sum = 0.0f32;
                    let mut j = s;
                    while j < e {
                        let word = p.signs[wbase + j / 64];
                        let upto = e.min((j / 64 + 1) * 64);
                        let mut bitpos = j % 64;
                        while j < upto {
                            // +x if bit set, −x otherwise, via sign-bit XOR.
                            let neg_mask = (!(word >> bitpos) & 1) as u32;
                            let flipped = f32::from_bits(x[j].to_bits() ^ (neg_mask << 31));
                            signed_sum += flipped;
                            j += 1;
                            bitpos += 1;
                        }
                    }
                    acc += p.mu[gbase + g] * group_sums[g] + p.alpha[gbase + g] * signed_sum;
                }
                *slot += acc;
            }
            plane = p.residual.as_deref();
        }
    }

    /// One row of the packed GEMM: accumulate every token's dot with row
    /// `r` across all bitplanes into `orow` (length = number of tokens).
    /// `xt` is the token-major transpose of the activation matrix;
    /// `gsums[t * groups_per_row ..]` are token t's per-group sums.
    fn row_tokens(&self, r: usize, xt: &Matrix, gsums: &[f32], orow: &mut [f32]) {
        let g = self.groups_per_row;
        orow.iter_mut().for_each(|v| *v = 0.0);
        let mut plane = Some(self);
        while let Some(p) = plane {
            let wbase = r * p.words_per_row;
            let gbase = r * p.groups_per_row;
            for (t, slot) in orow.iter_mut().enumerate() {
                let xrow = xt.row(t);
                let tg = &gsums[t * g..(t + 1) * g];
                let mut acc = 0.0f32;
                for (gi, &gsum) in tg.iter().enumerate() {
                    let s = gi * p.group_size;
                    let e = (s + p.group_size).min(p.cols);
                    let set = p.set_sum(wbase, s, e, xrow);
                    acc += p.mu[gbase + gi] * gsum + p.alpha[gbase + gi] * (2.0 * set - gsum);
                }
                *slot += acc;
            }
            plane = p.residual.as_deref();
        }
    }

    /// Packed multi-token GEMM: Y = Ŵ X (X: cols × n_tokens). Per-group
    /// activation sums are computed once per token and reused by every row
    /// and bitplane. Single-threaded form of [`Self::matmul_mt`].
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        self.matmul_mt(x, 1)
    }

    /// Packed GEMM with rows distributed over `threads` workers of the
    /// persistent pool. Falls back to single-thread below the
    /// [`PAR_WORK_MIN`] work threshold. The activation transpose and the
    /// per-token group sums come from the per-thread scratch (reused
    /// across layers of a coalesced serving batch).
    pub fn matmul_mt(&self, x: &Matrix, threads: usize) -> Matrix {
        assert_eq!(
            x.rows, self.cols,
            "packed matmul shape mismatch: {}x{} @ {}x{}",
            self.rows, self.cols, x.rows, x.cols
        );
        let mut xt = take_scratch_xt();
        x.transpose_into(&mut xt);
        let out = self.matmul_t(&xt, threads);
        put_scratch_xt(xt);
        out
    }

    /// Packed GEMM over a TOKEN-MAJOR activation matrix (`xt`: n_tokens ×
    /// cols, one token per row) — the transpose-free entry for callers
    /// that already hold tokens as rows (the transform-domain batched
    /// path, which would otherwise transpose twice per layer).
    pub fn matmul_t(&self, xt: &Matrix, threads: usize) -> Matrix {
        assert_eq!(xt.cols, self.cols, "token-major matmul dim mismatch");
        let n = xt.rows;
        let g = self.groups_per_row;
        // Per-token per-group activation sums, token-major, in the
        // thread's reusable scratch.
        let mut gsums = GEMM_SCRATCH.with(|s| std::mem::take(&mut s.borrow_mut().gsums));
        gsums.clear();
        gsums.resize(n * g, 0.0);
        for t in 0..n {
            let xrow = xt.row(t);
            let tg = &mut gsums[t * g..(t + 1) * g];
            for (gi, slot) in tg.iter_mut().enumerate() {
                let s = gi * self.group_size;
                let e = (s + self.group_size).min(self.cols);
                *slot = xrow[s..e].iter().sum();
            }
        }
        let mut out = Matrix::zeros(self.rows, n);
        self.for_each_row_par(&mut out, threads, |r, orow| {
            self.row_tokens(r, xt, &gsums, orow)
        });
        GEMM_SCRATCH.with(|s| s.borrow_mut().gsums = gsums);
        out
    }

    /// Run `row_fn(r, out_row_r)` over every output row of a GEMM: serial
    /// below the [`PAR_WORK_MIN`] work threshold (retuned from 1e7 when
    /// pooled dispatch replaced per-call thread spawning), else rows
    /// distributed over [`parallel_for`]. Together with the GEMV driver
    /// [`Self::for_each_y_par`] this is where the disjoint-row unsafe
    /// write lives — shared by every f32 and i8 entry point so the
    /// threshold and safety argument cannot diverge.
    fn for_each_row_par<F>(&self, out: &mut Matrix, threads: usize, row_fn: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let n = out.cols;
        let work = self.rows as f64 * self.cols as f64 * n as f64 * self.order() as f64;
        if threads <= 1 || work < PAR_WORK_MIN {
            for r in 0..self.rows {
                row_fn(r, &mut out.data[r * n..(r + 1) * n]);
            }
        } else {
            let optr = SendPtr(out.data.as_mut_ptr());
            parallel_for(self.rows, threads, |r| {
                let optr = &optr;
                // SAFETY: each worker writes a disjoint row of `out`.
                let orow = unsafe { std::slice::from_raw_parts_mut(optr.0.add(r * n), n) };
                row_fn(r, orow);
            });
        }
    }

    /// Precompute per-group sums of an activation vector (shared across all
    /// rows and bitplanes — the μ-term of the packed GEMV).
    pub fn group_sums(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut sums = vec![0.0f32; self.groups_per_row];
        for (g, sum) in sums.iter_mut().enumerate() {
            let s = g * self.group_size;
            let e = (s + self.group_size).min(self.cols);
            *sum = x[s..e].iter().sum();
        }
        sums
    }

    /// Bytes of storage the packed form actually holds resident: sign
    /// words plus the (α, μ) metadata at the f32 width it is stored and
    /// serialized at, over all bitplanes. (The paper's fp16-metadata *bit*
    /// accounting lives in [`crate::quant::group::QuantStats`]; this
    /// figure is the realized one the memory reports aggregate.)
    pub fn storage_bytes(&self) -> usize {
        let own = self.signs.len() * 8 + (self.alpha.len() + self.mu.len()) * 4;
        own + self.residual.as_deref().map_or(0, |r| r.storage_bytes())
    }

    /// Bytes the dense f32 form would take.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Compression ratio dense/packed.
    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.storage_bytes() as f64
    }

    /// Serialize the full bitplane chain (self-describing, little-endian).
    pub fn write_to<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        w.write_all(&(self.rows as u32).to_le_bytes())?;
        w.write_all(&(self.cols as u32).to_le_bytes())?;
        w.write_all(&(self.group_size as u32).to_le_bytes())?;
        w.write_all(&(self.order() as u32).to_le_bytes())?;
        let mut plane = Some(self);
        while let Some(p) = plane {
            for s in &p.signs {
                w.write_all(&s.to_le_bytes())?;
            }
            for a in &p.alpha {
                w.write_all(&a.to_le_bytes())?;
            }
            for m in &p.mu {
                w.write_all(&m.to_le_bytes())?;
            }
            plane = p.residual.as_deref();
        }
        Ok(())
    }

    /// Inverse of [`Self::write_to`] — bit-exact round-trip.
    pub fn read_from<R: std::io::Read>(r: &mut R) -> std::io::Result<Self> {
        fn read_u32<R: std::io::Read>(r: &mut R) -> std::io::Result<usize> {
            let mut buf = [0u8; 4];
            r.read_exact(&mut buf)?;
            Ok(u32::from_le_bytes(buf) as usize)
        }
        let rows = read_u32(r)?;
        let cols = read_u32(r)?;
        let group_size = read_u32(r)?;
        let order = read_u32(r)?;
        // Reject corrupt headers instead of coercing them: a zero
        // group_size would silently change the group layout, and huge
        // dims would allocate gigabytes before any data-length check.
        const DIM_CAP: usize = 1 << 24;
        if group_size == 0 || rows == 0 || cols == 0 || rows > DIM_CAP || cols > DIM_CAP {
            return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad packed dims"));
        }
        if order == 0 || order > 64 {
            return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad packed order"));
        }
        let words_per_row = cols.div_ceil(64);
        let groups_per_row = cols.div_ceil(group_size);
        // Buffers grow as data actually arrives (no zeroed pre-allocation
        // sized from the header), so a corrupt/truncated stream fails with
        // an io::Error after consuming at most what is present — never an
        // allocation-abort on a header promising terabytes.
        fn read_u64s<R: std::io::Read>(r: &mut R, n: usize) -> std::io::Result<Vec<u64>> {
            let mut out = Vec::new();
            let mut b8 = [0u8; 8];
            for _ in 0..n {
                r.read_exact(&mut b8)?;
                out.push(u64::from_le_bytes(b8));
            }
            Ok(out)
        }
        fn read_f32s<R: std::io::Read>(r: &mut R, n: usize) -> std::io::Result<Vec<f32>> {
            let mut out = Vec::new();
            let mut b4 = [0u8; 4];
            for _ in 0..n {
                r.read_exact(&mut b4)?;
                out.push(f32::from_le_bytes(b4));
            }
            Ok(out)
        }
        let mut planes = Vec::with_capacity(order);
        for _ in 0..order {
            let signs = read_u64s(r, rows * words_per_row)?;
            let alpha = read_f32s(r, rows * groups_per_row)?;
            let mu = read_f32s(r, rows * groups_per_row)?;
            planes.push(PackedBits {
                rows,
                cols,
                group_size,
                words_per_row,
                groups_per_row,
                signs,
                alpha,
                mu,
                residual: None,
            });
        }
        Ok(Self::chain_planes(planes))
    }
}

/// Raw-pointer wrapper so disjoint output rows can be written from the
/// thread pool (same idiom as `tensor::ops::matmul_mt`).
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul, matvec};
    use crate::util::rng::Rng;

    #[test]
    fn pack_dequant_is_group_binarization() {
        let mut rng = Rng::new(91);
        let w = Matrix::gauss(16, 200, 1.0, &mut rng);
        let p = PackedBits::pack(&w, 64);
        let d = p.dequantize();
        // Reconstruction must equal the dense group binarizer output.
        let spec = crate::quant::group::GroupSpec { group_size: 64, shared_mean: false, adaptive_split: false };
        let (q, _) = crate::quant::group::quantize_matrix(&w, &spec);
        assert!(d.dist_sq(&q) < 1e-9, "dist={}", d.dist_sq(&q));
    }

    #[test]
    fn packed_matvec_matches_dense() {
        let mut rng = Rng::new(92);
        for &(rows, cols, gs) in &[(8usize, 64usize, 32usize), (5, 130, 64), (3, 64, 64), (7, 100, 128)] {
            let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
            let x: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
            let p = PackedBits::pack(&w, gs);
            let dense = p.dequantize();
            let y_dense = matvec(&dense, &x);
            let mut y_packed = vec![0.0f32; rows];
            let gsums = p.group_sums(&x);
            p.matvec(&x, &gsums, &mut y_packed);
            for i in 0..rows {
                assert!(
                    (y_dense[i] - y_packed[i]).abs() < 1e-3 * (1.0 + y_dense[i].abs()),
                    "({rows},{cols},{gs}) row {i}: {} vs {}",
                    y_dense[i],
                    y_packed[i]
                );
            }
        }
    }

    #[test]
    fn word_at_a_time_matches_per_bit_reference() {
        let mut rng = Rng::new(95);
        let cases = [(6usize, 70usize, 64usize), (4, 130, 32), (5, 64, 128), (3, 200, 70)];
        for &(rows, cols, gs) in &cases {
            let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
            let x: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
            let p = PackedBits::pack_residual(&w, gs, 2, 0.0);
            let gsums = p.group_sums(&x);
            let mut y_new = vec![0.0f32; rows];
            let mut y_ref = vec![0.0f32; rows];
            p.matvec(&x, &gsums, &mut y_new);
            p.matvec_per_bit(&x, &gsums, &mut y_ref);
            for i in 0..rows {
                assert!(
                    (y_new[i] - y_ref[i]).abs() < 1e-3 * (1.0 + y_ref[i].abs()),
                    "({rows},{cols},{gs}) row {i}: {} vs {}",
                    y_new[i],
                    y_ref[i]
                );
            }
        }
    }

    #[test]
    fn packed_matmul_matches_dense_gemm() {
        let mut rng = Rng::new(96);
        let cases = [(8usize, 70usize, 64usize, 5usize), (6, 130, 32, 1), (5, 64, 64, 9)];
        for &(rows, cols, gs, n) in &cases {
            let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
            let x = Matrix::gauss(cols, n, 1.0, &mut rng);
            let p = PackedBits::pack(&w, gs);
            let y_dense = matmul(&p.dequantize(), &x);
            let y_packed = p.matmul(&x);
            assert_eq!((y_packed.rows, y_packed.cols), (rows, n));
            for i in 0..rows {
                for t in 0..n {
                    let (a, b) = (y_dense.at(i, t), y_packed.at(i, t));
                    assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "({i},{t}): {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn packed_matmul_mt_matches_st() {
        // Serial-vs-parallel bit-parity at the retuned threshold: work =
        // 96·256·32·2 ≈ 1.6e6 > PAR_WORK_MIN, so threads=4 genuinely fans
        // rows over the pool — and the output must be IDENTICAL (each row
        // is computed by the same per-row code regardless of thread
        // count), f32 and i8 both.
        let mut rng = Rng::new(97);
        let w = Matrix::gauss(96, 256, 1.0, &mut rng);
        let x = Matrix::gauss(256, 32, 1.0, &mut rng);
        let p = PackedBits::pack_residual(&w, 64, 2, 0.0);
        assert!(96.0 * 256.0 * 32.0 * 2.0 >= PAR_WORK_MIN, "test no longer crosses threshold");
        let a = p.matmul_mt(&x, 1);
        let b = p.matmul_mt(&x, 4);
        assert_eq!(a.data, b.data, "f32 GEMM must be thread-count invariant");
        let a8 = p.matmul_i8_mt(&x, 1);
        let b8 = p.matmul_i8_mt(&x, 4);
        assert_eq!(a8.data, b8.data, "i8 GEMM must be thread-count invariant");
    }

    #[test]
    fn matvec_mt_bit_identical_to_serial() {
        // Row-parallel single-token GEMV: above GEMV_PAR_MIN the rows fan
        // out; output must be bit-identical to the serial loop (f32 and
        // i8).
        let mut rng = Rng::new(105);
        let w = Matrix::gauss(256, 1030, 1.0, &mut rng); // 1030 = 16·64 + 6 tail
        let p = PackedBits::pack_residual(&w, 64, 2, 0.0);
        assert!(256.0 * 1030.0 * 2.0 >= GEMV_PAR_MIN, "test no longer crosses threshold");
        let x: Vec<f32> = (0..1030).map(|_| rng.gauss() as f32).collect();
        let gsums = p.group_sums(&x);
        let mut y1 = vec![0.0f32; 256];
        let mut y4 = vec![0.0f32; 256];
        p.matvec_mt(&x, &gsums, &mut y1, 1);
        p.matvec_mt(&x, &gsums, &mut y4, 4);
        assert_eq!(y1, y4, "f32 GEMV must be thread-count invariant");
        let act = p.quantize_act(&x);
        let mut z1 = vec![0.0f32; 256];
        let mut z4 = vec![0.0f32; 256];
        p.matvec_i8_mt(&act, &mut z1, 1);
        p.matvec_i8_mt(&act, &mut z4, 4);
        assert_eq!(z1, z4, "i8 GEMV must be thread-count invariant");
    }

    #[test]
    fn sliced_kernel_bit_identical_to_extraction() {
        // The tentpole identity: Σ_{b=0..6} 2^b·popcnt(S∧Q_b) −
        // 128·popcnt(S∧Q_7) over the fused column bit-slices must equal
        // the trailing_zeros extraction sum exactly, for every entry
        // point, on tails and multi-plane chains.
        let mut rng = Rng::new(106);
        for &(rows, cols, gs, order) in
            &[(8usize, 64usize, 32usize, 1usize), (6, 70, 64, 2), (5, 130, 128, 3), (4, 200, 7, 2)]
        {
            let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
            let p = PackedBits::pack_residual(&w, gs, order, 0.0);
            let x: Vec<f32> = (0..cols).map(|_| 2.0 * rng.gauss() as f32).collect();
            let act = p.quantize_act(&x);
            let mut y_sliced = vec![0.0f32; rows];
            let mut y_extract = vec![0.0f32; rows];
            p.matvec_i8(&act, &mut y_sliced);
            p.matvec_i8_extract(&act, &mut y_extract);
            assert_eq!(y_sliced, y_extract, "({rows},{cols},{gs},{order}) GEMV");
            let xb = Matrix::gauss(cols, 5, 1.0, &mut rng);
            let g_sliced = p.matmul_i8(&xb);
            let g_extract = p.matmul_i8_extract(&xb);
            assert_eq!(g_sliced.data, g_extract.data, "({rows},{cols},{gs},{order}) GEMM");
        }
    }

    #[test]
    fn sliced_kernel_handles_saturated_tokens() {
        // q = ±127 everywhere (all 7 magnitude bits + sign patterns that
        // exercise every plane, including the −128-weight plane 7 which
        // is set for every negative q).
        let mut rng = Rng::new(107);
        let w = Matrix::gauss(6, 70, 1.0, &mut rng);
        let p = PackedBits::pack_residual(&w, 64, 2, 0.0);
        let x: Vec<f32> = (0..70).map(|j| if j % 2 == 0 { 3.0 } else { -3.0 }).collect();
        let act = p.quantize_act(&x);
        assert!(act.q.iter().all(|&v| v == 127 || v == -127));
        let mut y_sliced = vec![0.0f32; 6];
        let mut y_extract = vec![0.0f32; 6];
        p.matvec_i8(&act, &mut y_sliced);
        p.matvec_i8_extract(&act, &mut y_extract);
        assert_eq!(y_sliced, y_extract);
    }

    #[test]
    fn every_simd_lane_bit_identical_to_extraction() {
        // The wide-lane tentpole contract: EVERY lane the host can run —
        // scalar, the portable 4×-unrolled lane, and (when detected) the
        // AVX2 lane — must reproduce the extraction reference exactly, on
        // word-aligned shapes, 70 = 64+6 tails, long multi-word interiors
        // that exercise the 4-word unrolled block, group sizes that split
        // words, multi-plane chains, and at thread counts 1 and 4.
        let mut rng = Rng::new(109);
        let shapes = [
            (8usize, 64usize, 32usize, 1usize),
            (6, 70, 64, 2),
            (5, 130, 128, 3),
            (4, 200, 7, 2),
            (3, 1030, 512, 2), // 16 words + 6-bit tail: interior unroll + remainder
        ];
        for lane in SimdLane::available() {
            for &(rows, cols, gs, order) in &shapes {
                let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
                let p = PackedBits::pack_residual(&w, gs, order, 0.0);
                let x: Vec<f32> = (0..cols).map(|_| 2.0 * rng.gauss() as f32).collect();
                let act = p.quantize_act(&x);
                let mut y_extract = vec![0.0f32; rows];
                p.matvec_i8_extract(&act, &mut y_extract);
                for threads in [1usize, 4] {
                    let mut y_lane = vec![0.0f32; rows];
                    p.matvec_i8_lane(&act, &mut y_lane, threads, lane);
                    assert_eq!(
                        y_lane,
                        y_extract,
                        "{} ({rows},{cols},{gs},{order}) t={threads} GEMV",
                        lane.label()
                    );
                }
                let xb = Matrix::gauss(cols, 5, 1.0, &mut rng);
                let g_lane = p.matmul_i8_lane(&xb, 2, lane);
                let g_extract = p.matmul_i8_extract(&xb);
                assert_eq!(
                    g_lane.data,
                    g_extract.data,
                    "{} ({rows},{cols},{gs},{order}) GEMM",
                    lane.label()
                );
            }
        }
    }

    #[test]
    fn every_simd_lane_handles_saturated_tokens() {
        // ±127 everywhere lights all 8 planes (plane 7 on every negative
        // q) — the combine-weight edge case, on every available lane.
        let mut rng = Rng::new(110);
        let w = Matrix::gauss(6, 70, 1.0, &mut rng);
        let p = PackedBits::pack_residual(&w, 64, 2, 0.0);
        let x: Vec<f32> = (0..70).map(|j| if j % 2 == 0 { 3.0 } else { -3.0 }).collect();
        let act = p.quantize_act(&x);
        assert!(act.q.iter().all(|&v| v == 127 || v == -127));
        let mut y_extract = vec![0.0f32; 6];
        p.matvec_i8_extract(&act, &mut y_extract);
        for lane in SimdLane::available() {
            let mut y_lane = vec![0.0f32; 6];
            p.matvec_i8_lane(&act, &mut y_lane, 1, lane);
            assert_eq!(y_lane, y_extract, "{}", lane.label());
        }
    }

    #[test]
    fn simd_lane_policy_is_consistent() {
        let avail = SimdLane::available();
        // The portable lanes run everywhere; the active lane is always an
        // available one; labels are distinct (they key the bench tables).
        assert!(avail.contains(&SimdLane::Scalar) && avail.contains(&SimdLane::Wide4));
        assert!(avail.contains(&SimdLane::active()));
        assert_eq!(avail.contains(&SimdLane::Avx2), avx2_available());
        let labels: Vec<&str> = avail.iter().map(|l| l.label()).collect();
        for (i, a) in labels.iter().enumerate() {
            assert!(labels[i + 1..].iter().all(|b| b != a), "duplicate lane label {a}");
        }
    }

    #[test]
    fn attn_precision_labels_and_parse_round_trip() {
        assert_eq!(AttnPrecision::parse("f32"), Some(AttnPrecision::F32));
        assert_eq!(AttnPrecision::parse("fp32"), Some(AttnPrecision::F32));
        assert_eq!(AttnPrecision::parse("int8"), Some(AttnPrecision::Int8));
        assert_eq!(AttnPrecision::parse("i8"), Some(AttnPrecision::Int8));
        assert_eq!(AttnPrecision::parse("w1a8"), None);
        for p in [AttnPrecision::F32, AttnPrecision::Int8] {
            assert_eq!(AttnPrecision::parse(p.label()), Some(p));
        }
        assert_eq!(AttnPrecision::default(), AttnPrecision::F32);
    }

    #[test]
    fn static_scale_quantization_saturates_and_matches_per_token_at_own_scale() {
        let mut rng = Rng::new(108);
        let w = Matrix::gauss(4, 70, 1.0, &mut rng);
        let p = PackedBits::pack(&w, 32);
        let x: Vec<f32> = (0..70).map(|_| rng.gauss() as f32).collect();
        // A static scale equal to the token's own per-token scale must
        // reproduce the per-token path bit-for-bit…
        let s_tok = crate::tensor::ops::act_scale_i8(&x);
        let y_static = p.matvec_i8_owned_with_scale(&x, Some(s_tok));
        let y_dyn = p.matvec_i8_owned(&x);
        assert_eq!(y_static, y_dyn);
        // …and a too-small calibrated scale saturates at ±127 instead of
        // overflowing (every |q| stays in range).
        let act = p.quantize_act_with_scale(&x, s_tok * 0.25);
        assert!(act.q.iter().all(|&v| (-127..=127).contains(&v)));
        assert!(act.q.iter().any(|&v| v == 127 || v == -127), "nothing saturated");
        // GEMM static path agrees with the GEMV static path per token.
        let xb = Matrix::gauss(70, 3, 1.0, &mut rng);
        let g = p.matmul_i8_with_scale(&xb, 1, Some(0.02));
        let xbt = xb.transpose();
        for t in 0..3 {
            let yv = p.matvec_i8_owned_with_scale(xbt.row(t), Some(0.02));
            for r in 0..4 {
                assert_eq!(g.at(r, t), yv[r], "({r},{t})");
            }
        }
    }

    #[test]
    fn matmul_t_matches_matmul() {
        // The token-major entry (transpose-free) must agree bit-for-bit
        // with the column-major wrapper.
        let mut rng = Rng::new(109);
        let w = Matrix::gauss(9, 70, 1.0, &mut rng);
        let p = PackedBits::pack_residual(&w, 64, 2, 0.0);
        let x = Matrix::gauss(70, 6, 1.0, &mut rng);
        let xt = x.transpose();
        assert_eq!(p.matmul(&x).data, p.matmul_t(&xt, 1).data);
        assert_eq!(p.matmul_i8(&x).data, p.matmul_i8_t(&xt, 1, None).data);
    }

    #[test]
    fn residual_planes_reduce_error_monotonically() {
        let mut rng = Rng::new(98);
        // Multi-level data (the transform-method reconstruction regime).
        let w = Matrix::from_fn(16, 128, |_, _| {
            let a = if rng.flip(0.5) { 1.0f32 } else { -1.0 };
            let b = if rng.flip(0.5) { 0.4f32 } else { -0.4 };
            a + b + 0.05 * rng.gauss() as f32
        });
        let denom = w.frob_norm_sq();
        let mut last = f64::INFINITY;
        for order in 1..=3 {
            let p = PackedBits::pack_residual(&w, 64, order, 0.0);
            assert_eq!(p.order(), order);
            let err = w.dist_sq(&p.dequantize()) / denom;
            assert!(err < last, "order {order}: {err} !< {last}");
            last = err;
        }
        // Two planes capture the ±a±b lattice almost exactly.
        let p2 = PackedBits::pack_residual(&w, 64, 2, 0.0);
        assert!(w.dist_sq(&p2.dequantize()) / denom < 0.05);
    }

    #[test]
    fn pack_deploy_meets_tolerance_on_lattice() {
        let mut rng = Rng::new(99);
        let w = Matrix::from_fn(32, 192, |_, _| {
            let a = if rng.flip(0.5) { 0.8f32 } else { -0.8 };
            let b = if rng.flip(0.5) { 0.3f32 } else { -0.3 };
            a + b
        });
        let p = PackedBits::pack_deploy(&w);
        let err = w.dist_sq(&p.dequantize()) / w.frob_norm_sq();
        assert!(err <= DEPLOY_REL_TOL * 1.5, "err={err}, order={}", p.order());
        assert!(p.order() <= DEPLOY_MAX_ORDER);
    }

    #[test]
    fn serialization_roundtrip_bit_exact() {
        let mut rng = Rng::new(100);
        let w = Matrix::gauss(9, 70, 1.0, &mut rng);
        let p = PackedBits::pack_residual(&w, 32, 3, 0.0);
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let q = PackedBits::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(q.order(), 3);
        assert_eq!((q.rows, q.cols, q.group_size), (9, 70, 32));
        let (d1, d2) = (p.dequantize(), q.dequantize());
        assert_eq!(d1.data, d2.data, "round-trip must be bit-exact");
        assert_eq!(p.storage_bytes(), q.storage_bytes());
    }

    #[test]
    fn read_from_fails_cleanly_on_truncated_oversized_header() {
        // rows and cols each pass the per-dimension cap and multiply to a
        // terabyte-scale promise; with no payload behind the header the
        // read must fail with an io::Error after consuming what exists —
        // not abort on a header-sized allocation.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(1u32 << 24).to_le_bytes()); // rows
        buf.extend_from_slice(&(1u32 << 24).to_le_bytes()); // cols
        buf.extend_from_slice(&1u32.to_le_bytes()); // group_size = 1 (worst metadata case)
        buf.extend_from_slice(&64u32.to_le_bytes()); // order
        assert!(PackedBits::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn compression_ratio_near_32x_for_large_groups() {
        let mut rng = Rng::new(93);
        let w = Matrix::gauss(256, 1024, 1.0, &mut rng);
        let p = PackedBits::pack(&w, 128);
        let r = p.compression_ratio();
        assert!(r > 20.0, "ratio={r}");
    }

    #[test]
    fn storage_accounting_sane() {
        let w = Matrix::zeros(4, 64);
        let p = PackedBits::pack(&w, 64);
        // 4 rows × 1 word × 8B signs + 4×(α+μ)×4B = 32 + 32 = 64.
        assert_eq!(p.storage_bytes(), 64);
        assert_eq!(p.dense_bytes(), 4 * 64 * 4);
        // A second bitplane doubles it.
        let p2 = PackedBits::pack_residual(&w, 64, 2, -1.0);
        assert_eq!(p2.storage_bytes(), 128);
    }

    #[test]
    fn matvec_owned_entry_points_agree() {
        // Regression for the group-sum recompute fix: the self-computing
        // entry point and the precomputed-sums entry point must agree
        // bit-for-bit (same kernel, same accumulation order).
        let mut rng = Rng::new(101);
        for &(rows, cols, gs) in &[(7usize, 70usize, 64usize), (5, 130, 32), (4, 64, 64)] {
            let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
            let x: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
            let p = PackedBits::pack_residual(&w, gs, 2, 0.0);
            let gsums = p.group_sums(&x);
            let y_auto = p.matvec_owned(&x);
            let y_pre = p.matvec_owned_with(&x, Some(&gsums));
            assert_eq!(y_auto, y_pre, "({rows},{cols},{gs})");
        }
    }

    #[test]
    fn quantize_act_matches_reference_quantizer() {
        // The fused quantize+group-sum pass must produce exactly the
        // reference quantization of tensor::ops, with group sums equal to
        // the sums of the quantized values.
        let mut rng = Rng::new(102);
        let w = Matrix::gauss(3, 70, 1.0, &mut rng);
        let p = PackedBits::pack(&w, 32);
        let x: Vec<f32> = (0..70).map(|_| 2.0 * rng.gauss() as f32).collect();
        let act = p.quantize_act(&x);
        let (q_ref, s_ref) = crate::tensor::ops::quantize_vec_i8(&x);
        assert_eq!(act.q, q_ref);
        assert_eq!(act.scale, s_ref);
        for (g, &gsum) in act.group_sums.iter().enumerate() {
            let s = g * 32;
            let e = (s + 32).min(70);
            let expect: i32 = act.q[s..e].iter().map(|&v| v as i32).sum();
            assert_eq!(gsum, expect, "group {g}");
        }
        // Zero token: zero scale, zero sums, zero output.
        let z = p.quantize_act(&vec![0.0f32; 70]);
        assert_eq!(z.scale, 0.0);
        assert!(z.group_sums.iter().all(|&v| v == 0));
        let mut y = vec![1.0f32; 3];
        p.matvec_i8(&z, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn i8_matvec_matches_f32_within_analytic_bound() {
        // |Ŵ x − Ŵ x̂| ≤ Σ_j |Ŵ_rj| · s_tok/2 per row: the i8 kernel's
        // only deviation from the f32 packed kernel is the activation
        // round-off, bounded elementwise by half the token scale.
        let mut rng = Rng::new(103);
        for &(rows, cols, gs, order) in
            &[(8usize, 64usize, 32usize, 1usize), (6, 70, 64, 2), (5, 130, 128, 1), (4, 200, 7, 2)]
        {
            let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
            let x: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
            let p = PackedBits::pack_residual(&w, gs, order, 0.0);
            let deq = p.dequantize();
            let gsums = p.group_sums(&x);
            let mut y32 = vec![0.0f32; rows];
            p.matvec(&x, &gsums, &mut y32);
            let act = p.quantize_act(&x);
            let mut y8 = vec![0.0f32; rows];
            p.matvec_i8(&act, &mut y8);
            for r in 0..rows {
                let abs_row: f32 = deq.row(r).iter().map(|v| v.abs()).sum();
                let bound = 0.5 * act.scale * abs_row * 1.001 + 1e-4;
                assert!(
                    (y32[r] - y8[r]).abs() <= bound,
                    "({rows},{cols},{gs},{order}) row {r}: {} vs {} (bound {bound})",
                    y32[r],
                    y8[r]
                );
            }
        }
    }

    #[test]
    fn i8_matmul_bit_identical_to_i8_matvec_per_token() {
        // GEMM and GEMV share row_acc_i8, so each column of the W1A8 GEMM
        // must equal the W1A8 GEMV of that column exactly — single- and
        // multi-threaded.
        let mut rng = Rng::new(104);
        let w = Matrix::gauss(9, 70, 1.0, &mut rng);
        let x = Matrix::gauss(70, 5, 1.0, &mut rng);
        let p = PackedBits::pack_residual(&w, 64, 2, 0.0);
        let y = p.matmul_i8(&x);
        let xt = x.transpose();
        for t in 0..5 {
            let yv = p.matvec_i8_owned(xt.row(t));
            for r in 0..9 {
                assert_eq!(y.at(r, t), yv[r], "({r},{t})");
            }
        }
        let big_w = Matrix::gauss(96, 256, 1.0, &mut rng);
        let big_x = Matrix::gauss(256, 32, 1.0, &mut rng);
        let bp = PackedBits::pack_residual(&big_w, 64, 2, 0.0);
        let a = bp.matmul_i8_mt(&big_x, 1);
        let b = bp.matmul_i8_mt(&big_x, 8);
        assert_eq!(a.data, b.data, "mt i8 GEMM must be deterministic");
    }

    #[test]
    fn non_multiple_group_sizes() {
        let mut rng = Rng::new(94);
        let w = Matrix::gauss(3, 70, 1.0, &mut rng); // 70 = 64 + 6 tail
        let p = PackedBits::pack(&w, 32);
        let d = p.dequantize();
        assert_eq!(d.cols, 70);
        assert!(d.is_finite());
    }
}
