//! Infrastructure substrates: deterministic RNG, scoped thread pool,
//! CLI parsing and progress reporting — all dependency-free (the usual
//! crates are unavailable in this offline build environment).

pub mod cli;
pub mod progress;
pub mod rng;
pub mod threadpool;
