//! Deterministic pseudo-random number generation.
//!
//! The whole reproduction must be bit-reproducible across runs, so every
//! stochastic component (weight init, scene sampling, episode noise,
//! diffusion sampling) draws from an explicitly seeded [`Rng`]. We implement
//! PCG64 (XSL-RR 128/64) seeded through SplitMix64, which gives excellent
//! statistical quality with a tiny, dependency-free footprint — the `rand`
//! crate is not available in this offline environment.

/// SplitMix64: used to expand a single `u64` seed into PCG state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic, decorrelated backoff jitter shared by every retry
/// loop in the system (fleet robot retries, router host re-dials): one
/// splitmix-style mix of `(key, attempt)` folded into `[0, base_us/2]`.
/// Same key and attempt → same jitter (reproducible runs); different
/// keys or attempts → decorrelated jitter (no retry lockstep, no
/// reconnect stampede).
#[inline]
pub fn backoff_jitter_us(key: u64, attempt: u32, base_us: u64) -> u64 {
    let mut z = key
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((attempt as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    z % (base_us / 2 + 1)
}

/// PCG64 XSL-RR generator. 128-bit state / 128-bit stream, 64-bit output.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// Cached second output of the Box–Muller transform.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Rng {
    /// Create a generator from a 64-bit seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id, so independent
    /// subsystems (sim, init, diffusion, …) can derive disjoint sequences
    /// from one experiment seed.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let mut sm2 = stream;
        let i0 = splitmix64(&mut sm2);
        let i1 = splitmix64(&mut sm2);
        let mut rng = Rng {
            state: ((s0 as u128) << 64) | s1 as u128,
            inc: (((i0 as u128) << 64) | i1 as u128) | 1,
            gauss_spare: None,
        };
        // Warm up: decorrelate from seed structure.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Derive a child generator; `tag` distinguishes siblings.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::with_stream(s, tag.wrapping_add(0x1234_5678_9ABC_DEF0))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^64 so the
        // modulo bias is negligible; we still do one widening multiply).
        let x = self.next_u64();
        ((x as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // Avoid u == 0.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Fill a slice with i.i.d. N(0, std²) f32 samples.
    pub fn fill_gauss(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = (self.gauss() as f32) * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli(p).
    #[inline]
    pub fn flip(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.uniform()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_uniformish() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        let s = r.sample_indices(100, 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
    }
}
