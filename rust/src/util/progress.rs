//! Lightweight progress / timing instrumentation for long eval runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// A named stopwatch that prints elapsed time on drop (opt-in via verbose).
pub struct Timer {
    label: String,
    start: Instant,
    verbose: bool,
}

impl Timer {
    pub fn new(label: &str, verbose: bool) -> Self {
        Timer { label: label.to_string(), start: Instant::now(), verbose }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        if self.verbose {
            eprintln!("[timer] {}: {:.3}s", self.label, self.elapsed_secs());
        }
    }
}

/// Thread-safe counter for coarse progress lines ("42/200 episodes").
pub struct Progress {
    label: String,
    total: usize,
    done: AtomicUsize,
    every: usize,
    verbose: bool,
}

impl Progress {
    pub fn new(label: &str, total: usize, verbose: bool) -> Self {
        let every = (total / 10).max(1);
        Progress { label: label.to_string(), total, done: AtomicUsize::new(0), every, verbose }
    }

    pub fn tick(&self) {
        let d = self.done.fetch_add(1, Ordering::Relaxed) + 1;
        if self.verbose && (d % self.every == 0 || d == self.total) {
            eprintln!("[{}] {}/{}", self.label, d, self.total);
        }
    }

    pub fn count(&self) -> usize {
        self.done.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn progress_counts() {
        let p = Progress::new("t", 10, false);
        for _ in 0..7 {
            p.tick();
        }
        assert_eq!(p.count(), 7);
    }

    #[test]
    fn timer_elapsed_nonnegative() {
        let t = Timer::new("x", false);
        assert!(t.elapsed_secs() >= 0.0);
    }
}
