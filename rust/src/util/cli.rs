//! Minimal command-line argument parsing (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a float, got '{v}'")))
            .unwrap_or(default)
    }

    /// A comma-separated list option (`--variants a,b,c`), empty tokens
    /// dropped. Falls back to parsing `default` the same way.
    pub fn list_or(&self, name: &str, default: &str) -> Vec<String> {
        self.get_or(name, default)
            .split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_positional_and_options() {
        let a = parse(&["eval", "--suite", "libero", "--episodes=20", "--verbose"]);
        assert_eq!(a.subcommand(), Some("eval"));
        assert_eq!(a.get("suite"), Some("libero"));
        assert_eq!(a.usize_or("episodes", 0), 20);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["run"]);
        assert_eq!(a.usize_or("episodes", 50), 50);
        assert_eq!(a.f64_or("tol", 0.5), 0.5);
        assert_eq!(a.get_or("suite", "simpler"), "simpler");
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn list_option() {
        let a = parse(&["fleet", "--variants", "dense, hbvla-packed,,"]);
        assert_eq!(a.list_or("variants", ""), vec!["dense", "hbvla-packed"]);
        assert_eq!(a.list_or("drills", "x,y"), vec!["x", "y"]);
        assert!(parse(&["fleet"]).list_or("variants", "").is_empty());
    }

    #[test]
    fn option_then_flag() {
        let a = parse(&["--seed", "7", "--obq"]);
        assert_eq!(a.u64_or("seed", 0), 7);
        assert!(a.flag("obq"));
    }
}
