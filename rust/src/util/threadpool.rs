//! Thread-parallel primitives backed by ONE persistent worker pool.
//!
//! `rayon` is not available offline, so the layer-parallel PTQ scheduler,
//! the rollout engine and the packed GEMM/GEMV kernels all share this
//! module. Historically every `parallel_for` call spawned fresh OS threads
//! through `std::thread::scope`; at serving granularity (one GEMM per
//! layer per batch) the spawn cost dominated small problems and forced the
//! kernels to keep high serial-fallback thresholds. The current design
//! keeps a lazily-initialized **global worker pool** (started on first
//! use, `default_threads()` workers, jobs over the same MPMC
//! channel-behind-a-mutex the serving [`Pool`] uses) and turns
//! `parallel_for` into: submit K helper jobs that pull indices from a
//! shared atomic counter, run the same loop on the calling thread, then
//! wait for the helpers to drain.
//!
//! Structured-parallelism safety: helpers register as *running* under a
//! per-call lock before touching the caller's closure; at drain time the
//! caller flips a cancelled flag under the same lock (helpers that have
//! not started become no-ops and never dereference the stack pointer)
//! and blocks until the running count reaches zero. Borrowing stack data
//! from the closure is sound because of that handshake — and one call's
//! latency never waits on another call's queue backlog, since queued
//! helpers are cancelled rather than awaited (the caller itself drains
//! the remaining items).
//!
//! Nesting: a `parallel_for` issued FROM a pool worker runs serially
//! inline. Helper jobs therefore never block on pool progress, which is
//! the no-deadlock invariant of the design (a blocked worker waiting for
//! queued helpers that only blocked workers could run). The outer level
//! owns the pool's parallelism; inner levels (e.g. a threaded GEMM inside
//! a layer-parallel PTQ job) degrade to the serial loop instead of
//! oversubscribing.
//!
//! Panics in the closure are caught per item, the pool workers survive,
//! and `parallel_for` re-raises after the barrier.
//!
//! Bit-parity across thread counts: every kernel that fans out over this
//! pool partitions OUTPUT elements (packed GEMM rows, PTQ layers), so
//! each element is computed by exactly one thread in a fixed operation
//! order — results are bit-identical at any thread count and on any
//! [`crate::quant::packed::SimdLane`]. The INT8 attention core
//! deliberately does NOT head-parallelize over this pool: attention runs
//! inside `linear`-dominated forwards that already own the pool at the
//! outer level (nested calls degrade to serial anyway), and the per-head
//! score/context loops are small enough that fan-out overhead would
//! exceed the work at MiniVLA scale.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Set for the lifetime of every global-pool worker thread: nested
    /// `parallel_for` calls detect it and run inline (see module docs).
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

struct GlobalPool {
    tx: std::sync::mpsc::Sender<Job>,
    threads: usize,
    /// Workers currently blocked waiting for a job — the submission
    /// heuristic: `parallel_for` only enqueues up to this many helpers,
    /// so a saturated pool degrades to the caller's serial loop instead
    /// of queuing dead jobs that would all cancel at drain time.
    idle: Arc<AtomicUsize>,
}

static GLOBAL: OnceLock<GlobalPool> = OnceLock::new();

/// The process-wide worker pool, started on first use. Workers are
/// detached (the pool lives for the process); a panicking job is caught
/// so the worker survives to run the next one.
fn global_pool() -> &'static GlobalPool {
    GLOBAL.get_or_init(|| {
        let threads = default_threads().max(1);
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let idle = Arc::new(AtomicUsize::new(0));
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let idle = Arc::clone(&idle);
            std::thread::Builder::new()
                .name(format!("hbvla-pool-{i}"))
                .spawn(move || {
                    IN_POOL_WORKER.with(|f| f.set(true));
                    loop {
                        idle.fetch_add(1, Ordering::Relaxed);
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        idle.fetch_sub(1, Ordering::Relaxed);
                        match job {
                            Ok(job) => {
                                // The job itself reports panics to its
                                // submitter (see parallel_for); this catch
                                // only keeps the worker alive.
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                            }
                            Err(_) => break, // channel closed (never, in practice)
                        }
                    }
                })
                .expect("spawn pool worker");
        }
        GlobalPool { tx, threads, idle }
    })
}

/// Worker count of the global pool (starts it if needed).
pub fn pool_threads() -> usize {
    global_pool().threads
}

/// Whether the current thread IS a global-pool worker (used by the
/// kernels to avoid nested submission; exposed for tests).
pub fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|f| f.get())
}

thread_local! {
    /// Per-thread fan-out cap for `parallel_for` (0 = uncapped). Set by
    /// [`with_thread_cap`] so concurrent batch dispatchers can co-plan:
    /// N serving workers each computing a batched forward divide the pool
    /// instead of all requesting full-width row-parallelism and
    /// serializing on the idle-count heuristic.
    static THREAD_CAP: Cell<usize> = const { Cell::new(0) };
}

/// Run `f` with every `parallel_for` issued from THIS thread capped at
/// `cap` helpers+caller (nested caps take the minimum; the previous cap
/// is restored on exit, even across panics). Capping only narrows the
/// fan-out, so results stay bit-identical — kernels partition output
/// elements deterministically at any thread count.
pub fn with_thread_cap<R>(cap: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_CAP.with(|c| c.set(self.0));
        }
    }
    let cap = cap.max(1);
    let _restore = Restore(THREAD_CAP.with(|c| {
        let prev = c.get();
        c.set(if prev == 0 { cap } else { prev.min(cap) });
        prev
    }));
    f()
}

/// The current thread's fan-out cap (0 = uncapped). Exposed for tests.
pub fn thread_cap() -> usize {
    THREAD_CAP.with(|c| c.get())
}

/// Run `f(i)` for i in 0..n across at most `threads` workers of the
/// persistent pool (plus the calling thread), blocking until all items
/// complete. Items are pulled dynamically (work stealing by atomic
/// counter), so uneven item costs balance well. Called from inside a pool
/// worker it degrades to the serial loop (see module docs).
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let mut threads = threads.max(1).min(n);
    let cap = THREAD_CAP.with(|c| c.get());
    if cap > 0 {
        threads = threads.min(cap);
    }
    if threads == 1 || in_pool_worker() {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let pool = global_pool();
    // The caller participates, so at most threads−1 helpers are
    // submitted — and never more than the pool's currently-idle worker
    // count (a racy heuristic: a stale read only costs some parallelism
    // for this one call, while submitting into a saturated pool would
    // queue boxed jobs that all cancel unrun at drain time).
    let helpers = (threads - 1).min(pool.threads).min(pool.idle.load(Ordering::Relaxed));
    if helpers == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    // Per-call handshake: helpers that have not STARTED by the time the
    // caller drains the work are cancelled (they check under the lock and
    // never touch the caller's stack), so one call's latency never waits
    // on another call's queue backlog — the caller only joins helpers
    // that are actively running its own closure.
    struct HelperSync {
        /// Erased pointer to the caller's `run` closure + its caller.
        raw: *const (),
        call: unsafe fn(*const ()),
        /// (cancelled, actively running helper count).
        state: Mutex<(bool, usize)>,
        cvar: Condvar,
    }
    // SAFETY: `raw` points at a Sync closure on the caller's stack; it is
    // only dereferenced by helpers that registered under the lock before
    // `cancelled` was set, and the caller blocks until their count drops
    // to zero — after cancellation the pointer is never read again.
    unsafe impl Send for HelperSync {}
    unsafe impl Sync for HelperSync {}
    fn erase<R: Fn() + Sync>(r: &R) -> (*const (), unsafe fn(*const ())) {
        unsafe fn call<R: Fn()>(p: *const ()) {
            (*(p as *const R))();
        }
        (r as *const R as *const (), call::<R>)
    }

    let counter = AtomicUsize::new(0);
    let panicked = AtomicBool::new(false);
    let payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    let run = || loop {
        if panicked.load(Ordering::Relaxed) {
            break;
        }
        let i = counter.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        if let Err(p) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))) {
            panicked.store(true, Ordering::Relaxed);
            payload.lock().unwrap().get_or_insert(p);
        }
    };
    let (raw, call) = erase(&run);
    let sync = Arc::new(HelperSync {
        raw,
        call,
        state: Mutex::new((false, 0)),
        cvar: Condvar::new(),
    });
    for _ in 0..helpers {
        let sync = Arc::clone(&sync);
        let job: Job = Box::new(move || {
            {
                let mut g = sync.state.lock().unwrap();
                if g.0 {
                    return; // cancelled before starting: caller is gone
                }
                g.1 += 1;
            }
            // SAFETY: registered as running under the lock above, so the
            // caller's drain below waits for this dereference to finish.
            unsafe { (sync.call)(sync.raw) };
            let mut g = sync.state.lock().unwrap();
            g.1 -= 1;
            if g.1 == 0 {
                sync.cvar.notify_all();
            }
        });
        pool.tx.send(job).expect("global pool closed");
    }
    run();
    {
        let mut g = sync.state.lock().unwrap();
        g.0 = true; // unstarted helpers become no-ops
        while g.1 > 0 {
            g = sync.cvar.wait(g).unwrap();
        }
    }
    if panicked.load(Ordering::Relaxed) {
        match payload.lock().unwrap().take() {
            Some(p) => std::panic::resume_unwind(p),
            None => panic!("parallel_for worker panicked"),
        }
    }
}

/// The pre-pool implementation — fresh scoped OS threads on every call.
/// Kept ONLY as the dispatch-overhead reference for
/// `benches/perf_micro.rs` and the §Perf baseline; production paths all
/// use [`parallel_for`].
pub fn parallel_for_spawn<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over 0..n in parallel, preserving order of results.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
        parallel_for(n, threads, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = Some(f(i));
        });
    }
    out.into_iter().map(|o| o.expect("worker panicked")).collect()
}

/// Default worker count: physical parallelism minus one (leave a core for
/// the coordinator), at least 1. Cached after the first query — the
/// kernel dispatch consults this per layer per token, and
/// `available_parallelism` is a syscall-backed probe that has no
/// business on that path.
pub fn default_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1).max(1))
            .unwrap_or(4)
    })
}

/// A persistent pool for the serving path: submit boxed jobs, each tagged
/// with a completion notification through a shared counter+condvar. Used by
/// the coordinator where job submission is dynamic (not a fixed range).
pub struct Pool {
    tx: std::sync::mpsc::Sender<Job>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut handles = Vec::new();
        for _ in 0..threads {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        job();
                        let (lock, cvar) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        if *p == 0 {
                            cvar.notify_all();
                        }
                    }
                    Err(_) => break, // channel closed: shut down
                }
            }));
        }
        Pool { tx, pending, handles }
    }

    /// Submit a job. Runs as soon as a worker is free.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.wait_idle();
        // Close the channel so workers exit, then join.
        let (tx, _) = std::sync::mpsc::channel::<Job>();
        drop(std::mem::replace(&mut self.tx, tx));
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all() {
        let hits = AtomicU64::new(0);
        parallel_for(1000, 8, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000 * 1001 / 2);
    }

    #[test]
    fn parallel_map_order() {
        let v = parallel_map(100, 7, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn parallel_for_single_thread() {
        let hits = AtomicU64::new(0);
        parallel_for(10, 1, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn parallel_for_empty() {
        parallel_for(0, 4, |_| panic!("should not run"));
    }

    #[test]
    fn parallel_for_reuses_pool_across_calls() {
        // Many successive calls against the persistent pool: coverage must
        // hold every round (the pool is shared process-wide, so this also
        // exercises interleaving with other tests' submissions).
        for round in 0..50 {
            let hits = AtomicU64::new(0);
            parallel_for(64, 4, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 64, "round {round}");
        }
    }

    #[test]
    fn nested_parallel_for_completes_without_deadlock() {
        // Outer items fan out over the pool; inner calls from pool workers
        // degrade to serial loops (the no-deadlock invariant).
        let hits = AtomicU64::new(0);
        parallel_for(4, 4, |_| {
            parallel_for(10, 4, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 40);
    }

    #[test]
    fn parallel_for_propagates_panic_and_pool_survives() {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_for(16, 4, |i| {
                if i == 7 {
                    panic!("intentional test panic");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // The pool workers caught the panic and keep serving jobs.
        let hits = AtomicU64::new(0);
        parallel_for(100, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn thread_cap_scopes_nest_and_restore() {
        assert_eq!(thread_cap(), 0, "uncapped by default");
        let out = with_thread_cap(4, || {
            assert_eq!(thread_cap(), 4);
            // Nested scopes take the minimum; widening is refused.
            with_thread_cap(2, || assert_eq!(thread_cap(), 2));
            with_thread_cap(8, || assert_eq!(thread_cap(), 4));
            assert_eq!(thread_cap(), 4);
            7
        });
        assert_eq!(out, 7);
        assert_eq!(thread_cap(), 0, "cap restored on exit");
        // Restored even when the closure panics.
        let r = std::panic::catch_unwind(|| with_thread_cap(3, || panic!("boom")));
        assert!(r.is_err());
        assert_eq!(thread_cap(), 0);
    }

    #[test]
    fn thread_cap_one_forces_serial_but_covers_all() {
        let hits = AtomicU64::new(0);
        let peak = AtomicU64::new(0);
        let live = AtomicU64::new(0);
        with_thread_cap(1, || {
            parallel_for(200, 8, |i| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
                live.fetch_sub(1, Ordering::SeqCst);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 200 * 201 / 2);
        assert_eq!(peak.load(Ordering::SeqCst), 1, "cap=1 must run serially");
    }

    #[test]
    fn spawn_reference_covers_all() {
        let hits = AtomicU64::new(0);
        parallel_for_spawn(300, 6, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 300 * 301 / 2);
    }

    #[test]
    fn pool_runs_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn pool_reusable_after_wait() {
        let pool = Pool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }
}
