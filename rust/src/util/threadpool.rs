//! A small scoped thread pool.
//!
//! `rayon` is not available offline, so the layer-parallel PTQ scheduler and
//! the rollout engine use this pool: a fixed set of workers pulling closures
//! from an MPMC channel built on `std::sync::mpsc` + a mutex-wrapped
//! receiver. `scope` provides structured parallelism: it blocks until every
//! job submitted inside the scope has finished, so borrows of stack data are
//! expressed safely via `std::thread::scope` underneath.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Run `f(i)` for i in 0..n across at most `threads` OS threads, blocking
/// until all items complete. Items are pulled dynamically (work stealing by
/// atomic counter), so uneven item costs balance well.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over 0..n in parallel, preserving order of results.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
        parallel_for(n, threads, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = Some(f(i));
        });
    }
    out.into_iter().map(|o| o.expect("worker panicked")).collect()
}

/// Default worker count: physical parallelism minus one (leave a core for
/// the coordinator), at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// A persistent pool for the serving path: submit boxed jobs, each tagged
/// with a completion notification through a shared counter+condvar. Used by
/// the coordinator where job submission is dynamic (not a fixed range).
pub struct Pool {
    tx: std::sync::mpsc::Sender<Job>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl Pool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut handles = Vec::new();
        for _ in 0..threads {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        job();
                        let (lock, cvar) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        if *p == 0 {
                            cvar.notify_all();
                        }
                    }
                    Err(_) => break, // channel closed: shut down
                }
            }));
        }
        Pool { tx, pending, handles }
    }

    /// Submit a job. Runs as soon as a worker is free.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.wait_idle();
        // Close the channel so workers exit, then join.
        let (tx, _) = std::sync::mpsc::channel::<Job>();
        drop(std::mem::replace(&mut self.tx, tx));
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all() {
        let hits = AtomicU64::new(0);
        parallel_for(1000, 8, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000 * 1001 / 2);
    }

    #[test]
    fn parallel_map_order() {
        let v = parallel_map(100, 7, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn parallel_for_single_thread() {
        let hits = AtomicU64::new(0);
        parallel_for(10, 1, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn parallel_for_empty() {
        parallel_for(0, 4, |_| panic!("should not run"));
    }

    #[test]
    fn pool_runs_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn pool_reusable_after_wait() {
        let pool = Pool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }
}
