//! Behavioural cloning: ridge-regression fits of the action heads on
//! expert demonstrations.
//!
//! The trunk (vision encoder, projector, language blocks) is a fixed
//! random-feature/constructed-grounding transformer; only the head layers
//! are fit, in closed form (normal equations via Cholesky) — no gradient
//! training anywhere in the stack, which keeps the whole reproduction
//! deterministic and fast. Head-specific targets:
//!
//! - **Chunk** (OFT-like): next `chunk` expert actions, flattened;
//! - **Token** (OpenVLA-like): one-hot action-bin indicators per dim
//!   (least-squares classifier, argmax decode);
//! - **Diffusion** (CogACT-like): per-step linear DDIM denoisers fit on
//!   synthetically noised expert actions along the deterministic path.

use crate::model::config::HeadKind;
use crate::model::MiniVla;
use crate::sim::episode::DemoStep;
use crate::tensor::linalg::ridge;
use crate::tensor::matrix::Matrix;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct FitReport {
    pub samples: usize,
    /// Mean-squared action error on the training set (continuous heads) or
    /// argmax accuracy (token head).
    pub train_metric: f64,
}

/// Fit `model`'s head on demonstrations, in place.
pub fn fit_policy(model: &mut MiniVla, demos: &[Vec<DemoStep>], lambda: f64) -> FitReport {
    // 1. Featurize every demo step with the FP trunk (+ head expansion).
    let feat_dim = model.cfg.head_in_dim();
    let mut feats: Vec<Vec<f32>> = Vec::new();
    let mut acts: Vec<[f32; 3]> = Vec::new();
    let mut traj_bounds: Vec<(usize, usize)> = Vec::new();
    let mut trunk_feats: Vec<Vec<f32>> = Vec::new();
    for demo in demos {
        let start = trunk_feats.len();
        for step in demo {
            let f = model.features(&step.obs.visual_raw, step.obs.instr_id, &step.obs.proprio, &mut None);
            trunk_feats.push(f);
            acts.push(step.action);
        }
        traj_bounds.push((start, trunk_feats.len()));
    }
    // Fit the head standardization (head.norm) on raw expanded features,
    // then re-expand through it.
    {
        let mut hn = Matrix::zeros(2, feat_dim);
        for j in 0..feat_dim {
            hn.set(1, j, 1.0);
        }
        model.store.set("head.norm", hn);
        let raw: Vec<Vec<f32>> = trunk_feats.iter().map(|f| model.head_features(f)).collect();
        let n = raw.len() as f32;
        let mut hn = Matrix::zeros(2, feat_dim);
        for j in 0..feat_dim {
            // Scale-only standardization: mean subtraction would break the
            // held-gate semantics (zeroed dims must stay zero).
            let ms: f32 = raw.iter().map(|r| r[j] * r[j]).sum::<f32>() / n;
            hn.set(0, j, 0.0);
            hn.set(1, j, ms.sqrt().max(1e-3));
        }
        model.store.set("head.norm", hn);
    }
    for f in &trunk_feats {
        feats.push(model.head_features(f));
    }
    let n = feats.len();
    assert!(n > 0, "no demo steps");
    let mut x = Matrix::zeros(n, feat_dim);
    for (i, f) in feats.iter().enumerate() {
        x.row_mut(i).copy_from_slice(f);
    }

    let cfg = model.cfg.clone();
    match cfg.head {
        HeadKind::Chunk => {
            // Targets: the next `chunk` actions within the trajectory
            // (repeat the last action past the end).
            let tdim = cfg.chunk * cfg.act_dim;
            let mut y = Matrix::zeros(n, tdim);
            for &(s, e) in &traj_bounds {
                for i in s..e {
                    for c in 0..cfg.chunk {
                        let src = (i + c).min(e - 1);
                        for d in 0..cfg.act_dim {
                            y.set(i, c * cfg.act_dim + d, acts[src][d]);
                        }
                    }
                }
            }
            let w = ridge(&x, &y, lambda);
            model.store.set("head.main", w.transpose());
            // Train metric: first-action MSE.
            let mut mse = 0.0f64;
            for i in 0..n {
                let pred = crate::tensor::ops::matvec(model.store.get("head.main"), x.row(i));
                for d in 0..cfg.act_dim {
                    mse += ((pred[d] - acts[i][d]) as f64).powi(2);
                }
            }
            FitReport { samples: n, train_metric: mse / (n * cfg.act_dim) as f64 }
        }
        HeadKind::Token => {
            // Regression fit; decode snaps to the bin grid (see
            // MiniVla::decode). Metric: post-discretization action MSE.
            let mut y = Matrix::zeros(n, cfg.act_dim);
            for i in 0..n {
                for d in 0..cfg.act_dim {
                    y.set(i, d, acts[i][d]);
                }
            }
            let w = ridge(&x, &y, lambda);
            model.store.set("head.main", w.transpose());
            let mut mse = 0.0f64;
            for i in 0..n {
                let pred = crate::tensor::ops::matvec(model.store.get("head.main"), x.row(i));
                for d in 0..cfg.act_dim {
                    let v = pred[d].clamp(-1.0, 1.0);
                    let b = (((v + 1.0) / 2.0 * cfg.bins as f32) as usize).min(cfg.bins - 1);
                    let q = -1.0 + 2.0 * (b as f32 + 0.5) / cfg.bins as f32;
                    mse += ((q - acts[i][d]) as f64).powi(2);
                }
            }
            FitReport { samples: n, train_metric: mse / (n * cfg.act_dim) as f64 }
        }
        HeadKind::Diffusion => {
            // Deterministic-path DDIM with ᾱ_t = 1 − (t+1)/T (ᾱ₋₁ ≡ 1).
            let t_steps = cfg.diffusion_steps;
            let alpha_bar = |t: i64| -> f32 {
                if t < 0 {
                    1.0
                } else {
                    1.0 - (t + 1) as f32 / t_steps as f32
                }
            };
            let mut rng = Rng::with_stream(cfg.seed ^ 0xD1FF, 0xBC);
            // Per-sample noise, shared across steps (deterministic path).
            let eps: Vec<[f32; 3]> = (0..n)
                .map(|_| [rng.gauss() as f32, rng.gauss() as f32, rng.gauss() as f32])
                .collect();
            let in_dim = cfg.act_dim + feat_dim + 1;
            let mut mse_last = 0.0f64;
            for t in (0..t_steps).rev() {
                let ab_t = alpha_bar(t as i64);
                let ab_prev = alpha_bar(t as i64 - 1);
                let (st, sn) = (ab_t.sqrt(), (1.0 - ab_t).sqrt());
                let (pt, pn) = (ab_prev.sqrt(), (1.0 - ab_prev).max(0.0).sqrt());
                let mut xin = Matrix::zeros(n, in_dim);
                let mut y = Matrix::zeros(n, cfg.act_dim);
                for i in 0..n {
                    for d in 0..cfg.act_dim {
                        let a0 = acts[i][d];
                        xin.set(i, d, st * a0 + sn * eps[i][d]);
                        y.set(i, d, pt * a0 + pn * eps[i][d]);
                    }
                    for (k, &f) in feats[i].iter().enumerate() {
                        xin.set(i, cfg.act_dim + k, f);
                    }
                    xin.set(i, cfg.act_dim + feat_dim, 1.0);
                }
                let w = ridge(&xin, &y, lambda);
                model.store.set(&format!("head.diff.{t}"), w.transpose());
                if t == 0 {
                    // Final-step training MSE against clean actions.
                    for i in 0..n {
                        let pred = crate::tensor::ops::matvec(
                            model.store.get("head.diff.0"),
                            xin.row(i),
                        );
                        for d in 0..cfg.act_dim {
                            mse_last += ((pred[d] - acts[i][d]) as f64).powi(2);
                        }
                    }
                    mse_last /= (n * cfg.act_dim) as f64;
                }
            }
            FitReport { samples: n, train_metric: mse_last }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::demos::collect_demos;
    use crate::model::{HeadKind, VlaConfig};
    use crate::sim::episode::run_policy_episode;
    use crate::sim::observe::ObsParams;
    use crate::sim::tasks::libero_suite;

    fn fit_and_eval(head: HeadKind, n_demo: usize, episodes: usize) -> f64 {
        let cfg = VlaConfig::tiny(head);
        let mut model = MiniVla::new(cfg);
        let tasks = libero_suite("object");
        let demos = collect_demos(&model, &tasks, n_demo, 11);
        let rep = fit_policy(&mut model, &demos, 1.0);
        assert!(rep.samples > 0);
        let mut ok = 0;
        for (i, task) in tasks.iter().cycle().take(episodes).enumerate() {
            if run_policy_episode(&model, task, &ObsParams::clean(), 1000 + i as u64).success {
                ok += 1;
            }
        }
        ok as f64 / episodes as f64
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "heavy; run with --release")]
    fn chunk_head_clones_expert_closed_loop() {
        let sr = fit_and_eval(HeadKind::Chunk, 32, 10);
        assert!(sr >= 0.5, "chunk-head closed-loop SR {sr}");
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "heavy; run with --release")]
    fn token_head_works() {
        let sr = fit_and_eval(HeadKind::Token, 32, 10);
        assert!(sr >= 0.4, "token-head closed-loop SR {sr}");
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "heavy; run with --release")]
    fn diffusion_head_works() {
        let sr = fit_and_eval(HeadKind::Diffusion, 32, 10);
        assert!(sr >= 0.4, "diffusion-head closed-loop SR {sr}");
    }

    #[test]
    fn chunk_train_mse_small() {
        let cfg = VlaConfig::tiny(HeadKind::Chunk);
        let mut model = MiniVla::new(cfg);
        let tasks = libero_suite("object");
        let demos = collect_demos(&model, &tasks, 16, 13);
        let rep = fit_policy(&mut model, &demos, 1.0);
        assert!(rep.train_metric < 0.08, "train action MSE {}", rep.train_metric);
    }
}
