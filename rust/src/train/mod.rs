//! Behavioural-cloning fits for the MiniVLA readout heads.

pub mod bc;

pub use bc::{fit_policy, FitReport};
