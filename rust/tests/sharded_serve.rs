//! Sharded-dispatch integration: the variant-affine sharded router must
//! be BYTE-IDENTICAL to sequential serving — which shard, worker, batch
//! window, or steal dispatched a request can never change its actions —
//! and routed admission must stop the cross-variant skew where one
//! variant's backlog shed another variant's requests.
//!
//! Shard placements are pinned by `shard_for` (pure FNV-1a over the
//! variant name): "dense" → shard 0 and "packed" → shard 1 under both 2
//! and 4 shards, and "fast" / "slow" land on different shards of 2 — so
//! these tests exercise real multi-shard routing, not a hash-collision
//! degenerate case.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use hbvla::coordinator::{
    quantize_into_registry, shard_for, AdmissionControl, ModelRegistry, PolicyServer, ServeConfig,
    ServeError, ServeRequest,
};
use hbvla::methods::traits::Component;
use hbvla::methods::HbVla;
use hbvla::model::{HeadKind, MiniVla, VlaConfig};
use hbvla::sim::observe::{observe, ObsParams, Observation};
use hbvla::sim::tasks::libero_suite;
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

/// Tiny chunk-head checkpoint with real head weights.
fn base_model() -> MiniVla {
    let mut m = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
    let mut rng = Rng::new(0xF00D);
    let (hr, hc) = m.store.dims("head.main");
    m.store.set("head.main", Matrix::gauss(hr, hc, 0.1, &mut rng));
    m
}

fn sample_obs(model: &MiniVla, seed: u64) -> Observation {
    let task = &libero_suite("object")[0];
    let mut rng = Rng::new(seed);
    let scene = task.instantiate(&mut rng);
    observe(&scene, task.stages[0].instr(), 100, model, &ObsParams::clean(), &mut rng)
}

#[test]
fn actions_and_variants_bit_identical_across_workers_and_shards() {
    let base = base_model();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    let calib = HashMap::new();
    let comps = [Component::Vision, Component::Language, Component::ActionHead];
    quantize_into_registry(&registry, "packed", &base, &calib, &HbVla::new(), &comps, 2).unwrap();
    // The two variants live on different shards in every sharded config.
    assert_ne!(shard_for("dense", 2), shard_for("packed", 2));
    assert_ne!(shard_for("dense", 4), shard_for("packed", 4));

    let names = ["dense", "packed"];
    let obs: Vec<Observation> = (0..12).map(|k| sample_obs(&base, 900 + k)).collect();
    // Sequential per-model reference (the Chunk head decode is
    // deterministic, so the reference needs no serving machinery at all).
    let reference: Vec<Vec<Vec<f32>>> = obs
        .iter()
        .enumerate()
        .map(|(k, o)| {
            let m = registry.get(names[k % 2]).unwrap();
            let f = m.features(&o.visual_raw, o.instr_id, &o.proprio, &mut None);
            m.decode(&f, &mut Rng::new(0))
        })
        .collect();

    let mut first: Option<Vec<(String, Vec<Vec<f32>>)>> = None;
    for workers in [1usize, 4] {
        for shards in [1usize, 2, 4] {
            let server = PolicyServer::start(
                Arc::clone(&registry),
                ServeConfig {
                    workers,
                    shards,
                    max_batch: 4,
                    max_wait: Duration::from_micros(200),
                    ..Default::default()
                },
            );
            assert_eq!(server.n_shards(), shards);
            // One interleaved async burst: batches, windows, and steals
            // compose differently per config — the answers must not.
            let handles: Vec<_> = obs
                .iter()
                .enumerate()
                .map(|(k, o)| {
                    server
                        .submit_async(ServeRequest::new(o.clone()).with_variant(names[k % 2]))
                        .unwrap()
                })
                .collect();
            let got: Vec<(String, Vec<Vec<f32>>)> = handles
                .into_iter()
                .map(|h| {
                    let r = h.wait().unwrap();
                    (r.variant_served, r.actions)
                })
                .collect();
            for (k, (v, a)) in got.iter().enumerate() {
                assert_eq!(v, names[k % 2], "workers={workers} shards={shards} request {k}");
                assert_eq!(
                    a, &reference[k],
                    "workers={workers} shards={shards} request {k}: sharded serving \
                     diverged from the sequential forward"
                );
            }
            match &first {
                None => first = Some(got),
                Some(f) => assert_eq!(
                    f, &got,
                    "workers={workers} shards={shards} differs from the first config"
                ),
            }
            server.shutdown();
        }
    }
}

#[test]
fn slow_variant_backlog_does_not_shed_fast_variant_requests() {
    // The cross-variant admission skew this PR fixes: under the old
    // GLOBAL-depth admission, a backlog on one (slow) variant raised the
    // global estimate and shed deadline-bearing requests for a DIFFERENT
    // variant whose own queue was idle. Routed admission prices only the
    // request's own shard, so the fast variant must be admitted (its
    // worst case is a deadline miss at dispatch — a triage outcome, never
    // an admission shed) while the slow variant is still shed.
    let base = base_model();
    let registry = Arc::new(ModelRegistry::new());
    // Same checkpoint under two names: the skew is queue-state, not
    // model-speed — distinct shards are all the scenario needs.
    registry.register("fast", Arc::new(base.clone())).unwrap();
    registry.register("slow", Arc::new(base.clone())).unwrap();
    assert_ne!(shard_for("fast", 2), shard_for("slow", 2));

    // One worker so the backlog cannot be drained (or stolen) mid-test;
    // max_batch 4 so warmup waves close on count, deterministically.
    let server = PolicyServer::start(
        Arc::clone(&registry),
        ServeConfig {
            workers: 1,
            shards: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            admission: AdmissionControl::DeadlineAware { min_samples: 4 },
        },
    );
    let obs = sample_obs(&base, 21);
    // Warm BOTH variants' service-rate statistics (cold stats never shed).
    for variant in ["fast", "slow"] {
        let wave: Vec<_> = (0..4)
            .map(|_| {
                server
                    .submit_async(ServeRequest::new(obs.clone()).with_variant(variant))
                    .unwrap()
            })
            .collect();
        for h in wave {
            h.wait().unwrap();
        }
    }
    // Backlog the slow shard: 5 async requests; the first window closes on
    // count and dispatches, but the remainder holds slow-shard depth ≥ 1
    // for the whole 50 ms window — eons next to the probes below.
    let backlog: Vec<_> = (0..5)
        .map(|_| server.submit_async(ServeRequest::new(obs.clone()).with_variant("slow")).unwrap())
        .collect();

    // Probe 1: the SLOW variant behind its own backlog is shed.
    let deadline = Duration::from_nanos(1);
    let err = server
        .submit(ServeRequest::new(obs.clone()).with_variant("slow").with_deadline(deadline))
        .unwrap_err();
    assert!(
        matches!(err, ServeError::Overloaded { .. }),
        "slow variant behind its own backlog must shed, got {err:?}"
    );

    // Probe 2 — the regression: the FAST variant's shard is idle, so the
    // same impossible deadline must be ADMITTED (global-depth admission
    // shed it here). Its fate downstream is deadline triage, not a shed.
    let fast_probe = server
        .submit_async(ServeRequest::new(obs.clone()).with_variant("fast").with_deadline(deadline))
        .expect("fast variant on an idle shard must be admitted despite the slow backlog");

    // Drain everything; the fast probe's only acceptable failure is the
    // dispatch-time deadline miss.
    match fast_probe.wait() {
        Err(ServeError::DeadlineExceeded { .. }) => {}
        other => panic!("expected DeadlineExceeded for a 1ns deadline, got {other:?}"),
    }
    for h in backlog {
        h.wait().unwrap();
    }
    let per = server.variant_stats();
    assert_eq!(per["slow"].admission_sheds, 1, "slow probe shed at submit");
    assert_eq!(per["fast"].admission_sheds, 0, "fast variant must never shed for slow backlog");
    assert_eq!(per["fast"].deadline_misses, 1, "fast probe triaged at dispatch");
    server.shutdown();
}
