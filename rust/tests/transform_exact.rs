//! Transform-domain exact serving: acceptance wall.
//!
//! `hbvla-exact` serves the committed Haar-domain bitplanes with ZERO
//! residual planes by executing y = C·haar(Pᵀx) on the activation side.
//! Pinned here:
//!   (a) forward parity with the offline reconstruction within float
//!       roundoff — per layer (including 70 = 64+6 word-tail columns) and
//!       end-to-end on every head kind;
//!   (b) sequential-vs-batched bit-parity per request, f32 and W1A8,
//!       through `features_batch` and through a live `PolicyServer`;
//!   (c) serialized-store (v3 `HBVLAPS3`) round-trip bit-exactness;
//! plus the memory claim — the exact commit drops the residual-plane
//! bytes the repacked commit pays — and the typed `UnsupportedRepr` error
//! when exact serving is requested from a direct-domain method.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use hbvla::coordinator::{
    quantize_exact_into_registry, quantize_model, quantize_model_exact, ModelRegistry,
    PolicyServer, RegistryError, ServeConfig, ServeRequest,
};
use hbvla::methods::traits::{CalibData, Component};
use hbvla::methods::{HbVla, Rtn};
use hbvla::model::vla::ObsInput;
use hbvla::model::{ActPrecision, DeployRepr, HeadKind, MiniVla, VlaConfig, WeightRepr};
use hbvla::sim::observe::{observe, ObsParams, Observation};
use hbvla::sim::tasks::libero_suite;
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

const ALL: [Component; 3] = [Component::Vision, Component::Language, Component::ActionHead];

fn sample_obs(model: &MiniVla, seed: u64) -> Observation {
    let task = &libero_suite("object")[0];
    let mut rng = Rng::new(seed);
    let scene = task.instantiate(&mut rng);
    observe(&scene, task.stages[0].instr(), 100, model, &ObsParams::clean(), &mut rng)
}

fn exact_model(head: HeadKind) -> MiniVla {
    let base = MiniVla::new(VlaConfig::tiny(head));
    let calib = HashMap::new();
    let (qm, rep) =
        quantize_model_exact(&base, &calib, &HbVla::new(), &ALL, 2, "hbvla-exact").unwrap();
    assert!(rep.transform_layers > 0);
    assert_eq!(qm.cfg.deploy_repr, DeployRepr::TransformExact);
    qm
}

/// (a) Layer-level: the transform forward equals the dense product of its
/// own offline reconstruction within float roundoff — including the
/// 70 = 64 + 6 sign-word tail and odd widths.
#[test]
fn layer_forward_parity_with_offline_reconstruction() {
    let mut rng = Rng::new(31);
    for &(rows, cols) in &[(12usize, 70usize), (8, 64), (6, 33), (9, 136), (5, 9)] {
        let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
        let calib = CalibData::identity(cols, Component::Language);
        let q = HbVla::new().quantize(&w, &calib);
        let t = q.transform_packed.expect("HBVLA commits the transform form");
        // Zero residual planes is structural, not tolerance-dependent.
        assert_eq!(t.bits.order(), 1, "({rows},{cols})");
        let deq = t.dequantize();
        for trial in 0..4 {
            let x: Vec<f32> = (0..cols).map(|_| 2.0 * rng.gauss() as f32).collect();
            let y = t.matvec_owned(&x);
            let y_ref = hbvla::tensor::ops::matvec(&deq, &x);
            for r in 0..rows {
                assert!(
                    (y[r] - y_ref[r]).abs() < 1e-3 * (1.0 + y_ref[r].abs()),
                    "({rows},{cols}) trial {trial} row {r}: {} vs {}",
                    y[r],
                    y_ref[r]
                );
            }
        }
    }
}

/// (a) End-to-end: the exact model's forward matches its dense twin (the
/// store-wide offline reconstruction) on every head kind.
#[test]
fn every_head_kind_matches_dense_twin_of_exact_store() {
    for head in [HeadKind::Token, HeadKind::Chunk, HeadKind::Diffusion] {
        let qm = exact_model(head);
        assert!(qm.store.transform_packed_layer_count() > 0);
        let mut twin = qm.clone();
        assert!(twin.store.dequantize_all() > 0);
        let obs = sample_obs(&qm, 11);
        let f_exact = qm.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        let f_twin = twin.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        let scale: f32 = f_twin.iter().map(|v| v.abs()).fold(0.0, f32::max).max(1.0);
        for (k, (a, b)) in f_exact.iter().zip(&f_twin).enumerate() {
            assert!(
                (a - b).abs() < 1e-3 * scale,
                "{head:?} feature {k}: {a} vs {b}"
            );
        }
        let a_exact = qm.decode(&f_exact, &mut Rng::new(3));
        let a_twin = twin.decode(&f_twin, &mut Rng::new(3));
        for (ca, cb) in a_exact.iter().zip(&a_twin) {
            for (a, b) in ca.iter().zip(cb) {
                assert!((a - b).abs() < 1e-2, "{head:?}: {a} vs {b}");
            }
        }
    }
}

/// (b) Batched forward bit-parity: `features_batch` must reproduce each
/// request's solo `features` exactly on the exact store — f32 AND W1A8
/// (the transform is applied per token column; the packed GEMM shares the
/// GEMV's accumulation order; the fused activation scale equals the
/// batched one bit-for-bit).
#[test]
fn features_batch_bit_identical_f32_and_int8() {
    let mut qm = exact_model(HeadKind::Chunk);
    let obs: Vec<Observation> = (0..4).map(|k| sample_obs(&qm, 40 + k)).collect();
    for prec in [ActPrecision::F32, ActPrecision::Int8] {
        qm.store.set_act_precision(prec);
        let inputs: Vec<ObsInput> = obs
            .iter()
            .map(|o| ObsInput {
                visual_raw: &o.visual_raw,
                instr_id: o.instr_id,
                proprio: &o.proprio,
            })
            .collect();
        let batched = qm.features_batch(&inputs);
        for (k, o) in obs.iter().enumerate() {
            let solo = qm.features(&o.visual_raw, o.instr_id, &o.proprio, &mut None);
            assert_eq!(batched[k], solo, "{prec:?} request {k} diverged under batching");
        }
    }
}

/// (b) Through the serving router: coalesced `hbvla-exact` requests are
/// bit-identical to the exact model's own sequential forward, per request.
#[test]
fn served_batches_bit_identical_to_sequential_exact_forward() {
    let base = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    let calib = HashMap::new();
    let rep = quantize_exact_into_registry(
        &registry,
        "hbvla-exact",
        &base,
        &calib,
        &HbVla::new(),
        &ALL,
        2,
    )
    .unwrap();
    assert_eq!(rep.transform_layers, rep.packed_layers);
    let served = registry.get("hbvla-exact").unwrap();
    assert!(served.store.transform_packed_layer_count() > 0);

    let server = PolicyServer::start(
        Arc::clone(&registry),
        ServeConfig {
            workers: 1,
            max_batch: 6,
            max_wait: Duration::from_millis(500),
            ..Default::default()
        },
    );
    let obs: Vec<Observation> = (0..6).map(|k| sample_obs(&base, 60 + k)).collect();
    let handles: Vec<_> = obs
        .iter()
        .map(|o| {
            server.submit_async(ServeRequest::new(o.clone()).with_variant("hbvla-exact")).unwrap()
        })
        .collect();
    let responses: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    assert!(server.batch_stats().max_recent() >= 2, "requests never coalesced");
    for (o, rsp) in obs.iter().zip(&responses) {
        assert_eq!(rsp.variant_served, "hbvla-exact");
        let feat = served.features(&o.visual_raw, o.instr_id, &o.proprio, &mut None);
        let expect = served.decode(&feat, &mut Rng::new(0));
        assert_eq!(rsp.actions, expect, "batched exact serve diverged from sequential");
    }
    server.shutdown();
}

/// (c) Store serialization v3: the transform-packed store round-trips
/// bit-exactly through disk, and the reloaded model's forward is
/// bit-identical.
#[test]
fn v3_store_roundtrip_bit_exact_and_forward_identical() {
    let qm = exact_model(HeadKind::Chunk);
    let path = std::env::temp_dir().join("hbvla_transform_exact_store.bin");
    qm.store.save(&path).unwrap();
    let loaded_store = hbvla::model::ParamStore::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(
        loaded_store.transform_packed_layer_count(),
        qm.store.transform_packed_layer_count()
    );
    assert_eq!(loaded_store.resident_weight_bytes(), qm.store.resident_weight_bytes());
    for p in qm.store.params() {
        let (a, b) = (qm.store.dense_view(&p.name), loaded_store.dense_view(&p.name));
        assert_eq!(a.data, b.data, "layer {} not bit-exact through v3", p.name);
    }
    let loaded = MiniVla { cfg: qm.cfg.clone(), store: loaded_store };
    let obs = sample_obs(&qm, 77);
    let f0 = qm.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
    let f1 = loaded.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
    assert_eq!(f0, f1, "reloaded exact store must forward bit-identically");
}

/// Exact serving drops the residual-plane memory: same checkpoint, same
/// method, the `hbvla-exact` store is strictly smaller resident than the
/// `hbvla-packed` store (which pays order-K planes to absorb
/// reconstruction error the exact form doesn't have) — and every
/// transform layer holds exactly one plane.
#[test]
fn exact_store_smaller_than_repacked_store() {
    let base = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
    let calib = HashMap::new();
    let (repacked, rep_r) = quantize_model(&base, &calib, &HbVla::new(), &ALL, 2);
    let (exact, rep_e) =
        quantize_model_exact(&base, &calib, &HbVla::new(), &ALL, 2, "hbvla-exact").unwrap();
    assert_eq!(rep_r.packed_layers, rep_e.packed_layers);
    assert!(
        exact.store.resident_weight_bytes() < repacked.store.resident_weight_bytes(),
        "exact {} !< repacked {}",
        exact.store.resident_weight_bytes(),
        repacked.store.resident_weight_bytes()
    );
    for p in exact.store.params() {
        if let WeightRepr::TransformPacked(t) = &p.repr {
            assert_eq!(t.bits.order(), 1, "layer {} has residual planes", p.name);
        }
    }
    // Both deploy forms stay in the structured-accuracy regime.
    assert!(rep_e.mean_deploy_rel_err < 0.25, "{rep_e:?}");
}

/// Requesting exact serving from a direct-domain method is a typed error
/// (`UnsupportedRepr`), never a silent fallback to the repack.
#[test]
fn exact_from_direct_domain_method_is_typed_error() {
    let base = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
    let registry = ModelRegistry::new();
    let calib = HashMap::new();
    let err = quantize_exact_into_registry(
        &registry,
        "rtn-exact",
        &base,
        &calib,
        &Rtn::new(),
        &[Component::Language],
        2,
    )
    .unwrap_err();
    assert!(
        matches!(err, RegistryError::UnsupportedRepr { ref variant, .. } if variant == "rtn-exact"),
        "{err:?}"
    );
    assert!(registry.get("rtn-exact").is_none());
}
