//! Packed-vs-dense forward parity: a MiniVLA whose every quantizable
//! layer is `WeightRepr::Packed` must match the forward pass of its dense
//! twin (the same store with each packed layer replaced by its
//! dequantization) — the property that makes the packed kernels the
//! *deployed* kernels rather than an approximation of them.

use hbvla::model::{HeadKind, MiniVla, VlaConfig};
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

/// Build (packed model, dense twin) with every quantizable layer packed at
/// `group_size`. Heads get non-zero weights so the decode path is
/// exercised too.
fn twins(cfg: VlaConfig, group_size: usize) -> (MiniVla, MiniVla) {
    let mut packed = MiniVla::new(cfg);
    let mut rng = Rng::new(0x7A17);
    let head_names: Vec<String> = if packed.store.contains("head.main") {
        vec!["head.main".to_string()]
    } else {
        (0..packed.cfg.diffusion_steps).map(|t| format!("head.diff.{t}")).collect()
    };
    for name in &head_names {
        let (hr, hc) = packed.store.dims(name);
        packed.store.set(name, Matrix::gauss(hr, hc, 0.05, &mut rng));
    }
    let n = packed.store.pack_quantizable(group_size);
    assert!(n > 0, "nothing packed");
    let mut dense = packed.clone();
    assert_eq!(dense.store.dequantize_all(), n);
    (packed, dense)
}

fn rand_obs(cfg: &VlaConfig, rng: &mut Rng) -> (Matrix, usize, Vec<f32>) {
    let v = Matrix::gauss(cfg.d_vis_in, cfg.n_visual, 1.0, rng);
    let p: Vec<f32> = (0..cfg.d_proprio).map(|_| rng.gauss() as f32).collect();
    (v, 3, p)
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < tol * (1.0 + y.abs()),
            "{what}[{i}]: packed {x} vs dense {y}"
        );
    }
}

#[test]
fn full_forward_parity_every_head() {
    for head in [HeadKind::Token, HeadKind::Chunk, HeadKind::Diffusion] {
        let cfg = VlaConfig::tiny(head);
        let (packed, dense) = twins(cfg.clone(), 64);
        let mut rng = Rng::new(301);
        for trial in 0..3 {
            let (v, i, p) = rand_obs(&cfg, &mut rng);
            let fp = packed.features(&v, i, &p, &mut None);
            let fd = dense.features(&v, i, &p, &mut None);
            assert_close(&fp, &fd, 1e-3, &format!("{head:?} trial {trial} features"));
        }
    }
}

#[test]
fn decode_parity_chunk_and_diffusion() {
    // Continuous heads decode identically (Token's bin edges can flip on
    // float-noise knife edges, so it is covered at the feature level).
    for head in [HeadKind::Chunk, HeadKind::Diffusion] {
        let cfg = VlaConfig::tiny(head);
        let (packed, dense) = twins(cfg.clone(), 64);
        let mut rng = Rng::new(302);
        let (v, i, p) = rand_obs(&cfg, &mut rng);
        // Identical rng streams on both sides (diffusion noise).
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        let ap = packed.act(&v, i, &p, &mut rng_a);
        let ad = dense.act(&v, i, &p, &mut rng_b);
        assert_eq!(ap.len(), ad.len());
        for (ca, cb) in ap.iter().zip(&ad) {
            assert_close(ca, cb, 1e-2, &format!("{head:?} action"));
        }
    }
}

#[test]
fn parity_with_tail_group_sizes() {
    // d_model = 70 ⇒ layer widths of 70 = 64 + 6: one full sign word plus
    // a 6-bit tail, and group sizes (64, 32) that do not divide the width.
    let mut cfg = VlaConfig::tiny(HeadKind::Chunk);
    cfg.d_model = 70;
    cfg.heads = 2; // 70 / 2 = 35 per head
    for gs in [64usize, 32] {
        let (packed, dense) = twins(cfg.clone(), gs);
        let mut rng = Rng::new(303);
        for trial in 0..2 {
            let (v, i, p) = rand_obs(&cfg, &mut rng);
            let fp = packed.features(&v, i, &p, &mut None);
            let fd = dense.features(&v, i, &p, &mut None);
            assert_close(&fp, &fd, 1e-3, &format!("gs={gs} trial {trial}"));
        }
    }
}

#[test]
fn packed_store_is_smaller_and_forward_finite() {
    let cfg = VlaConfig::tiny(HeadKind::Chunk);
    let (packed, dense) = twins(cfg.clone(), 64);
    assert!(
        packed.store.resident_weight_bytes() < dense.store.resident_weight_bytes(),
        "{} !< {}",
        packed.store.resident_weight_bytes(),
        dense.store.resident_weight_bytes()
    );
    let mut rng = Rng::new(304);
    let (v, i, p) = rand_obs(&cfg, &mut rng);
    let f = packed.features(&v, i, &p, &mut None);
    assert!(f.iter().all(|x| x.is_finite()));
}
