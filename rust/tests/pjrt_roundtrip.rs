//! Three-layer composition proof: the AOT-lowered JAX/Pallas policy graph
//! (L2+L1), executed by the Rust PJRT runtime (L3), must agree with the
//! Rust-native forward pass on the same weights and observations.
//!
//! Requires `make artifacts` (skipped with a notice otherwise, so plain
//! `cargo test` works before the Python build step) and the `xla-runtime`
//! feature (the xla PJRT bindings ship with the XLA toolchain image).
#![cfg(feature = "xla-runtime")]

use hbvla::model::{HeadKind, MiniVla, VlaConfig};
use hbvla::runtime::{artifacts_dir, PolicyRuntime};
use hbvla::sim::observe::{observe, ObsParams};
use hbvla::sim::tasks::libero_suite;
use hbvla::util::rng::Rng;

fn runtime_or_skip() -> Option<PolicyRuntime> {
    let dir = artifacts_dir();
    if !dir.join("policy_step.hlo.txt").exists() {
        eprintln!("[skip] artifacts missing — run `make artifacts`");
        return None;
    }
    Some(PolicyRuntime::load(&dir).expect("artifacts present but unloadable"))
}

#[test]
fn pjrt_policy_matches_native_forward() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = MiniVla::new(VlaConfig::base(HeadKind::Chunk));
    assert_eq!(rt.weight_order.len(), 37, "manifest drifted from the model layout");
    let tasks = libero_suite("object");
    let mut rng = Rng::new(77);
    for trial in 0..5 {
        let task = &tasks[trial % tasks.len()];
        let scene = task.instantiate(&mut rng);
        let obs = observe(&scene, task.stages[0].instr(), 100, &model, &ObsParams::clean(), &mut rng);
        let pjrt_act = rt
            .step(&model, &obs.visual_raw, obs.instr_id, &obs.proprio)
            .expect("pjrt step failed");
        let native = model.act(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut rng);
        assert_eq!(pjrt_act.len(), native.len());
        for (a, b) in pjrt_act.iter().flatten().zip(native.iter().flatten()) {
            assert!(
                (a - b).abs() < 5e-3,
                "trial {trial}: pjrt {a} vs native {b}"
            );
        }
    }
}

#[test]
fn pjrt_runs_quantized_weights() {
    // The deploy story: feed binarized weights through the SAME graph.
    let Some(rt) = runtime_or_skip() else { return };
    let model = MiniVla::new(VlaConfig::base(HeadKind::Chunk));
    let mut qm = model.clone();
    let comps = [hbvla::methods::Component::Vision, hbvla::methods::Component::Language];
    for name in model.store.quantizable_layers(Some(&comps)) {
        let w = model.store.get(&name);
        let cd = hbvla::methods::CalibData::identity(w.cols, model.store.component_of(&name));
        use hbvla::methods::Binarizer as _;
        let q = hbvla::methods::HbVla::new().quantize(w, &cd);
        qm.store.set(&name, q.w_hat);
    }
    let tasks = libero_suite("object");
    let mut rng = Rng::new(78);
    let scene = tasks[0].instantiate(&mut rng);
    let obs = observe(&scene, tasks[0].stages[0].instr(), 100, &qm, &ObsParams::clean(), &mut rng);
    let pjrt_act = rt.step(&qm, &obs.visual_raw, obs.instr_id, &obs.proprio).expect("pjrt step");
    let native = qm.act(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut rng);
    for (a, b) in pjrt_act.iter().flatten().zip(native.iter().flatten()) {
        assert!((a - b).abs() < 5e-3, "pjrt {a} vs native {b}");
    }
}

#[test]
fn binary_linear_kernel_artifact_matches_packed_gemv() {
    // The L1 Pallas kernel (interpret-lowered) vs the Rust packed GEMV.
    let dir = artifacts_dir();
    let path = dir.join("binary_linear.hlo.txt");
    if !path.exists() {
        eprintln!("[skip] artifacts missing — run `make artifacts`");
        return;
    }
    let client = xla::PjRtClient::cpu().expect("pjrt cpu");
    let exe = hbvla::runtime::HloExecutable::load(&client, &path).expect("load kernel");
    let (rows, cols, gs) = (128usize, 256usize, 128usize);
    let mut rng = Rng::new(9);
    let w = hbvla::tensor::Matrix::gauss(rows, cols, 1.0, &mut rng);
    let packed = hbvla::quant::packed::PackedBits::pack(&w, gs);
    let dense = packed.dequantize();
    // Reconstruct the kernel inputs (signs, alpha, mu) from the packed rep
    // via the dense dequant: signs = sign(dense - mu broadcast).
    let groups = cols / gs;
    let mut signs = vec![0f32; rows * cols];
    let mut alpha = vec![0f32; rows * groups];
    let mut mu = vec![0f32; rows * groups];
    for r in 0..rows {
        for g in 0..groups {
            let s = g * gs;
            let seg: Vec<f32> = (s..s + gs).map(|j| w.at(r, j)).collect();
            let m: f32 = seg.iter().sum::<f32>() / gs as f32;
            let a: f32 = seg.iter().map(|v| (v - m).abs()).sum::<f32>() / gs as f32;
            mu[r * groups + g] = m;
            alpha[r * groups + g] = a;
            for (k, &v) in seg.iter().enumerate() {
                signs[r * cols + s + k] = if v >= m { 1.0 } else { -1.0 };
            }
        }
    }
    let x: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
    let out = exe
        .run_f32(&[
            (&signs, vec![rows as i64, cols as i64]),
            (&alpha, vec![rows as i64, groups as i64]),
            (&mu, vec![rows as i64, groups as i64]),
            (&x, vec![cols as i64]),
        ])
        .expect("kernel exec");
    let y_dense = hbvla::tensor::ops::matvec(&dense, &x);
    for r in 0..rows {
        assert!(
            (out[0][r] - y_dense[r]).abs() < 1e-2 * (1.0 + y_dense[r].abs()),
            "row {r}: kernel {} vs dense {}",
            out[0][r],
            y_dense[r]
        );
    }
}
