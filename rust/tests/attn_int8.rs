//! INT8 attention integration: the quantized attention core
//! (`AttnPrecision::Int8`) against its f32 twin on every action-head
//! kind, a first-principles error bound on one attention block, and
//! sequential-vs-batched bit-parity through the serving stack for the
//! `*-a8` variant whose attention rides along to int8.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use hbvla::coordinator::{
    quantize_into_registry, register_a8_variant, ModelRegistry, PolicyServer, ServeConfig,
    ServeRequest,
};
use hbvla::methods::traits::Component;
use hbvla::methods::HbVla;
use hbvla::model::layers::{attn_forward_seg, linear};
use hbvla::model::{AttnPrecision, HeadKind, MiniVla, ParamStore, VlaConfig};
use hbvla::sim::observe::{observe, ObsParams, Observation};
use hbvla::sim::tasks::libero_suite;
use hbvla::tensor::ops::{act_scale_i8, quantize_i8, softmax_rows};
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

/// Tiny checkpoint with real (random) head weights for the given kind.
fn head_model(kind: HeadKind, seed: u64) -> MiniVla {
    let mut m = MiniVla::new(VlaConfig::tiny(kind));
    let mut rng = Rng::new(seed);
    match kind {
        HeadKind::Token | HeadKind::Chunk => {
            let (hr, hc) = m.store.dims("head.main");
            m.store.set("head.main", Matrix::gauss(hr, hc, 0.1, &mut rng));
        }
        HeadKind::Diffusion => {
            for t in 0..m.cfg.diffusion_steps {
                let name = format!("head.diff.{t}");
                let (hr, hc) = m.store.dims(&name);
                m.store.set(&name, Matrix::gauss(hr, hc, 0.1, &mut rng));
            }
        }
    }
    m
}

fn sample_obs(model: &MiniVla, seed: u64) -> Observation {
    let task = &libero_suite("object")[0];
    let mut rng = Rng::new(seed);
    let scene = task.instantiate(&mut rng);
    observe(&scene, task.stages[0].instr(), 100, model, &ObsParams::clean(), &mut rng)
}

/// On every head kind, the int8 attention core tracks the f32 core
/// through the full trunk (small but nonzero relative feature error) and
/// the decoded actions stay finite. The nonzero check guards against the
/// dispatch silently falling back to the f32 path.
#[test]
fn int8_attention_tracks_f32_on_every_head_kind() {
    for (kind, seed) in
        [(HeadKind::Token, 301u64), (HeadKind::Chunk, 302), (HeadKind::Diffusion, 303)]
    {
        let m32 = head_model(kind, seed);
        let m8 = m32.clone().with_attn_precision(AttnPrecision::Int8);
        assert_eq!(m8.store.attn_precision(), AttnPrecision::Int8);
        let obs = sample_obs(&m32, seed);
        let f32_feat = m32.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        let i8_feat = m8.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
        assert_eq!(f32_feat.len(), i8_feat.len());
        let (mut d2, mut n2) = (0.0f64, 0.0f64);
        for (a, b) in i8_feat.iter().zip(&f32_feat) {
            d2 += ((a - b) as f64).powi(2);
            n2 += (*b as f64).powi(2);
        }
        let rel = d2 / n2.max(1e-12);
        assert!(rel > 0.0, "{kind:?}: int8 attention never diverged — f32 fallback suspected");
        assert!(rel < 5e-2, "{kind:?}: relative trunk-feature error {rel}");
        let actions = m8.decode(&i8_feat, &mut Rng::new(0));
        assert!(!actions.is_empty(), "{kind:?}");
        for chunk in &actions {
            assert!(chunk.iter().all(|a| a.is_finite()), "{kind:?}: non-finite action");
        }
        // The continuous-regression head is smooth in its features, so
        // pin actual action closeness there (token/diffusion heads have
        // discrete or iterative decoders where tiny feature shifts may
        // legitimately switch bins).
        if kind == HeadKind::Chunk {
            let a32 = m32.decode(&f32_feat, &mut Rng::new(0));
            for (ca, cb) in actions.iter().zip(&a32) {
                for (x8, x32) in ca.iter().zip(cb) {
                    assert!((x8 - x32).abs() < 0.1 * (1.0 + x32.abs()), "{x8} vs {x32}");
                }
            }
        }
    }
}

/// One attention block, first-principles error accounting: the int8
/// output must sit inside the analytic bound assembled from the three
/// quantization stages —
///   scores:  |Δs[t,u]| ≤ scale·Σ_i(|q_it|·sk_u/2 + (|k_iu|+sk_u/2)·sq_t/2)
///   softmax: ‖Δp_t‖₁ ≤ 2·max_u |Δs[t,u]|   (ℓ∞→ℓ1 Jacobian norm ≤ 2)
///   context: max_u|v_iu|·‖Δp_t‖₁ + sv_max/2 + (sr_t/2)·Σ_u|v̂_iu|
/// pushed through |wo|. Only the kernel's *scale rules* are replicated to
/// recover sq/sk/sv/sr — every bound term is derived, not measured.
#[test]
fn int8_attention_block_error_within_analytic_bound() {
    let (d, heads, tokens) = (16usize, 4usize, 6usize);
    let dh = d / heads;
    let mut rng = Rng::new(0xA77);
    let mut store = ParamStore::new();
    for name in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
        store.insert(name, Component::Language, true, Matrix::gauss(d, d, 0.4, &mut rng));
    }
    let x = Matrix::gauss(d, tokens, 1.0, &mut rng);
    let y32 = attn_forward_seg(&store, "attn", heads, &x, tokens, &mut None);
    store.set_attn_precision(AttnPrecision::Int8);
    let y8 = attn_forward_seg(&store, "attn", heads, &x, tokens, &mut None);
    assert!(y8.dist_sq(&y32) > 0.0, "int8 attention bit-equal to f32 — f32 fallback suspected");

    // Recompute the projections the block used (same kernels, same store).
    let q = linear(&store, "attn.wq", &x);
    let k = linear(&store, "attn.wk", &x);
    let v = linear(&store, "attn.wv", &x);
    let scale = 1.0 / (dh as f32).sqrt();

    let mut ctx_bound = Matrix::zeros(d, tokens);
    for h in 0..heads {
        let r0 = h * dh;
        // Per-token column scales, exactly the kernel's rule (max/127).
        let col_scales = |m: &Matrix| -> Vec<f32> {
            (0..tokens)
                .map(|t| {
                    let mut mx = 0.0f32;
                    for i in 0..dh {
                        mx = mx.max(m.at(r0 + i, t).abs());
                    }
                    mx / 127.0
                })
                .collect()
        };
        let sq = col_scales(&q);
        let sk = col_scales(&k);
        let sv = col_scales(&v);
        // Score-stage bound, per row t (worst column u).
        let mut dmax = vec![0.0f32; tokens];
        for t in 0..tokens {
            for u in 0..tokens {
                let mut db = 0.0f32;
                for i in 0..dh {
                    db += q.at(r0 + i, t).abs() * sk[u] * 0.5
                        + (k.at(r0 + i, u).abs() + sk[u] * 0.5) * sq[t] * 0.5;
                }
                dmax[t] = dmax[t].max(scale * db);
            }
        }
        // Replicate the kernel's quantized probabilities only to recover
        // the probability-row scale sr (a scale, not a bound term).
        let quant = |val: f32, s: f32| -> i32 {
            if s > 0.0 {
                quantize_i8(val, 1.0 / s) as i32
            } else {
                0
            }
        };
        let mut p8 = Matrix::zeros(tokens, tokens);
        for t in 0..tokens {
            for u in 0..tokens {
                let mut acc = 0i32;
                for i in 0..dh {
                    acc += quant(q.at(r0 + i, t), sq[t]) * quant(k.at(r0 + i, u), sk[u]);
                }
                p8.set(t, u, scale * sq[t] * sk[u] * acc as f32);
            }
        }
        softmax_rows(&mut p8);
        let sv_max = sv.iter().cloned().fold(0.0f32, f32::max);
        for t in 0..tokens {
            let pr: Vec<f32> = (0..tokens).map(|u| p8.at(t, u) * sv[u]).collect();
            let sr = act_scale_i8(&pr);
            for i in 0..dh {
                let maxv = (0..tokens).map(|u| v.at(r0 + i, u).abs()).fold(0.0f32, f32::max);
                let vhat_l1: f32 = (0..tokens)
                    .map(|u| quant(v.at(r0 + i, u), sv[u]).abs() as f32)
                    .sum();
                let b = maxv * 2.0 * dmax[t] + 0.5 * sv_max + 0.5 * sr * vhat_l1;
                ctx_bound.set(r0 + i, t, b);
            }
        }
    }
    // y − x = wo·ctx for both precisions, so |y8 − y32| ≤ |wo|·Δctx-bound
    // elementwise (1.5× slack + tiny absolute term for f32 rounding).
    let wo = store.get("attn.wo");
    for i in 0..d {
        for t in 0..tokens {
            let mut bound = 0.0f32;
            for j in 0..d {
                bound += wo.at(i, j).abs() * ctx_bound.at(j, t);
            }
            let delta = (y8.at(i, t) - y32.at(i, t)).abs();
            assert!(
                delta <= bound * 1.5 + 1e-4,
                "row {i} tok {t}: |Δ| = {delta} exceeds analytic bound {bound}"
            );
        }
    }
}

/// The `-a8` twin registered through the scheduler serves with INT8
/// attention (policy inheritance), and a coalesced batch through the
/// PolicyServer is bit-identical to that model's own sequential forward —
/// the segment-local int8 core cannot let tokens of one request perturb
/// another.
#[test]
fn batched_a8_serving_with_int8_attention_bit_identical_to_sequential() {
    let base = head_model(HeadKind::Chunk, 0xF00D);
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    let calib = HashMap::new();
    let comps = [Component::Vision, Component::Language, Component::ActionHead];
    quantize_into_registry(&registry, "hbvla-packed", &base, &calib, &HbVla::new(), &comps, 2)
        .unwrap();
    let a8_name = register_a8_variant(&registry, "hbvla-packed").unwrap();
    let m8 = registry.get(&a8_name).unwrap();
    assert_eq!(m8.store.attn_precision(), AttnPrecision::Int8, "a8 twin must inherit int8 attn");

    let server = PolicyServer::start(
        Arc::clone(&registry),
        ServeConfig {
            workers: 1,
            max_batch: 6,
            max_wait: Duration::from_millis(500),
            ..Default::default()
        },
    );
    let obs: Vec<Observation> = (0..6).map(|k| sample_obs(&base, 700 + k)).collect();
    let handles: Vec<_> = obs
        .iter()
        .map(|o| {
            server.submit_async(ServeRequest::new(o.clone()).with_variant(&a8_name)).unwrap()
        })
        .collect();
    let responses: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    assert!(server.batch_stats().max_recent() >= 2, "requests never coalesced");
    for (o, rsp) in obs.iter().zip(&responses) {
        assert_eq!(rsp.variant_served, a8_name);
        let feat = m8.features(&o.visual_raw, o.instr_id, &o.proprio, &mut None);
        let expect = m8.decode(&feat, &mut Rng::new(0));
        assert_eq!(rsp.actions, expect, "batched int8-attention serve diverged from sequential");
    }
    server.shutdown();
}
