//! Fleet-harness integration: closed-loop determinism across worker
//! counts, and fault drills degrading gracefully with typed errors only.
//!
//! The acceptance property from the chunk head's serving guarantee
//! (batched ≡ sequential, decode consumes no server-side randomness):
//! a fixed fleet seed must reproduce bit-identical per-robot trajectory
//! digests and identical fleet report counters whether the server runs
//! one worker or four — only latency numbers may move.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use hbvla::coordinator::{quantize_into_registry, ModelRegistry, PolicyServer, ServeConfig};
use hbvla::fleet::{run_fleet, Drill, FleetConfig, FleetError, FleetReport};
use hbvla::methods::traits::Component;
use hbvla::methods::HbVla;
use hbvla::model::{HeadKind, MiniVla, VlaConfig};
use hbvla::sim::observe::ObsParams;
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

/// Tiny chunk-head checkpoint with real head weights, plus its packed
/// 1-bit commit — the minimal two-variant serving menu.
fn fleet_registry() -> Arc<ModelRegistry> {
    let mut base = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
    let mut rng = Rng::new(0xF1EE7);
    let (hr, hc) = base.store.dims("head.main");
    base.store.set("head.main", Matrix::gauss(hr, hc, 0.1, &mut rng));
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    let comps = [Component::Vision, Component::Language, Component::ActionHead];
    let rep = quantize_into_registry(
        &registry,
        "hbvla-packed",
        &base,
        &HashMap::new(),
        &HbVla::new(),
        &comps,
        2,
    )
    .unwrap();
    assert!(rep.packed_layers > 0, "{rep:?}");
    registry
}

fn run_with_workers(
    registry: &Arc<ModelRegistry>,
    cfg: &FleetConfig,
    workers: usize,
) -> FleetReport {
    let server = PolicyServer::start(
        Arc::clone(registry),
        ServeConfig {
            workers,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            ..Default::default()
        },
    );
    let report = run_fleet(registry, &server, cfg, &ObsParams::clean()).unwrap();
    server.shutdown();
    report
}

/// Every submit is answered OK or lands in exactly one typed error
/// counter — nothing silent, nothing lost.
fn assert_accounting_closed(report: &FleetReport) {
    let mut total_ok = 0;
    for row in &report.rows {
        assert_eq!(
            row.submits,
            row.responses_ok + row.admission_sheds + row.deadline_misses + row.errors,
            "accounting leak in variant '{}': {row:?}",
            row.variant
        );
        total_ok += row.responses_ok;
    }
    assert_eq!(total_ok, report.total_responses);
    assert_eq!(report.rows.iter().map(|r| r.robots).sum::<usize>(), report.robots);
}

#[test]
fn fixed_seed_reproduces_identical_reports_across_worker_counts() {
    let registry = fleet_registry();
    let cfg = FleetConfig {
        robots: 6,
        horizon: 12,
        variants: vec!["dense".into(), "hbvla-packed".into()],
        seed: 11,
        ..Default::default()
    };
    let one = run_with_workers(&registry, &cfg, 1);
    let four = run_with_workers(&registry, &cfg, 4);
    assert_accounting_closed(&one);
    assert_accounting_closed(&four);
    assert_eq!(one.total_responses, four.total_responses);
    assert_eq!(one.rows.len(), four.rows.len());
    for (a, b) in one.rows.iter().zip(&four.rows) {
        assert_eq!(a.variant, b.variant);
        // Bit-identical per-robot trajectories => identical variant digest.
        assert_eq!(a.digest, b.digest, "variant '{}' trajectories diverged", a.variant);
        assert_eq!(a.successes, b.successes);
        assert_eq!(a.reference_successes, b.reference_successes);
        assert_eq!(a.submits, b.submits);
        assert_eq!(a.responses_ok, b.responses_ok);
        assert_eq!((a.retries, a.admission_sheds, a.deadline_misses), (0, 0, 0));
        assert_eq!((b.retries, b.admission_sheds, b.deadline_misses), (0, 0, 0));
        assert_eq!((a.errors, a.dropped), (0, 0));
        assert_eq!((b.errors, b.dropped), (0, 0));
        // Divergence sums fold in robot-id order on both sides: exact.
        for (ba, bb) in a.divergence.iter().zip(&b.divergence) {
            assert_eq!(ba.count, bb.count);
            assert_eq!(ba.mean_l2, bb.mean_l2);
        }
        if a.variant == "dense" {
            // Robots served by the reference variant replay the reference
            // trajectory exactly: zero divergence in every bin.
            assert_eq!(a.max_divergence, 0.0, "dense-vs-dense must be exact");
            assert!(a.divergence.iter().all(|bin| bin.mean_l2 == 0.0));
            assert_eq!(a.successes, a.reference_successes);
        }
        if a.variant == "hbvla-packed" {
            assert!(
                a.divergence.iter().map(|bin| bin.count).sum::<u64>() > 0,
                "packed robots recorded no divergence samples"
            );
        }
    }
}

#[test]
fn worker_loss_drill_answers_every_request() {
    let registry = fleet_registry();
    let cfg = FleetConfig {
        robots: 8,
        horizon: 12,
        variants: vec!["dense".into(), "hbvla-packed".into()],
        seed: 23,
        drills: vec![Drill::WorkerLoss],
        ..Default::default()
    };
    let report = run_with_workers(&registry, &cfg, 4);
    assert_accounting_closed(&report);
    // The drill fired and halved capacity…
    assert_eq!(report.drill_report.workers_before_loss, 4);
    assert_eq!(report.drill_report.workers_after_loss, 2);
    assert!(report.live_workers_at_end >= 1);
    // …yet no request was silently dropped and no robot aborted: with no
    // deadline in play every submit must come back served.
    for row in &report.rows {
        assert!(row.submits > 0);
        assert_eq!(row.responses_ok, row.submits, "variant '{}' lost requests", row.variant);
        assert_eq!((row.errors, row.retries, row.dropped), (0, 0, 0));
    }
}

#[test]
fn hotspot_and_overload_drills_complete_with_typed_errors_only() {
    let registry = fleet_registry();
    let cfg = FleetConfig {
        robots: 8,
        horizon: 12,
        variants: vec!["dense".into(), "hbvla-packed".into()],
        seed: 31,
        // Hotspot first (fires at 1/3 progress, while everyone is still
        // live), then the overload burst at 2/3.
        drills: vec![Drill::Hotspot, Drill::Overload],
        ..Default::default()
    };
    let report = run_with_workers(&registry, &cfg, 2);
    assert_accounting_closed(&report);
    let d = &report.drill_report;
    // Hotspot: traffic collapsed onto the first NON-reference variant —
    // never onto the reference, whose row anchors zero divergence.
    assert_eq!(d.hotspot_variant.as_deref(), Some("hbvla-packed"));
    assert!(d.hotspot_switched >= 1, "{d:?}");
    // 4 of 8 robots started on each variant; every switch moves one
    // robot off dense and onto the hot packed variant.
    let dense_row = report.rows.iter().find(|r| r.variant == "dense").unwrap();
    let packed_row = report.rows.iter().find(|r| r.variant == "hbvla-packed").unwrap();
    assert_eq!(packed_row.robots as u64, 4 + d.hotspot_switched);
    assert_eq!(dense_row.robots as u64, 4 - d.hotspot_switched);
    // Serving-variant attribution: rehomed robots' dense-served steps
    // stay on the dense row (still exactly zero divergence — the
    // anchor survives the drill), and their post-switch packed-served
    // steps land on the packed row.
    assert!(dense_row.submits > 0);
    assert!(dense_row.divergence.iter().all(|b| b.mean_l2 == 0.0), "{dense_row:?}");
    assert!(packed_row.divergence.iter().map(|b| b.count).sum::<u64>() > 0);
    // Overload: at least one synchronized burst was released.
    assert!(d.overload_bursts >= 1, "{d:?}");
    assert!(d.max_burst_size >= 1);
    // Graceful degradation: every robot still finished, nothing dropped.
    for row in &report.rows {
        assert_eq!(row.responses_ok, row.submits);
        assert_eq!((row.errors, row.dropped), (0, 0));
    }
}

#[test]
fn fleet_config_errors_are_typed() {
    let registry = fleet_registry();
    let server = PolicyServer::start(Arc::clone(&registry), ServeConfig::default());
    let params = ObsParams::clean();
    let bad = FleetConfig {
        robots: 2,
        horizon: 4,
        variants: vec!["no-such-variant".into()],
        ..Default::default()
    };
    assert_eq!(
        run_fleet(&registry, &server, &bad, &params).unwrap_err(),
        FleetError::UnknownVariant("no-such-variant".into())
    );
    let none = FleetConfig { robots: 0, variants: vec!["dense".into()], ..Default::default() };
    assert_eq!(run_fleet(&registry, &server, &none, &params).unwrap_err(), FleetError::NoRobots);
    let empty = FleetConfig { robots: 2, variants: Vec::new(), ..Default::default() };
    assert_eq!(run_fleet(&registry, &server, &empty, &params).unwrap_err(), FleetError::NoVariants);
    server.shutdown();
}
