//! Randomized property tests (hand-rolled generators — proptest is not
//! available offline): invariants of the quantization core swept over
//! random shapes, seeds and parameter regimes.

use hbvla::haar::{
    haar_act_fwd_vec, haar_fwd_vec, haar_inv_vec, haar_rows, haar_rows_inv, half_len,
    pairwise_highpass_energy,
};
use hbvla::methods::{paper_methods, CalibData, Component};
use hbvla::quant::group::{quantize_matrix, GroupSpec};
use hbvla::quant::packed::{PackedBits, SimdLane};
use hbvla::quant::permute::{pairing_and_chaining, NormKind};
use hbvla::tensor::ops::{dequantize_vec_i8, gram, matvec, quantize_vec_i8};
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

fn random_shape(rng: &mut Rng) -> (usize, usize) {
    (4 + rng.below(60), 4 + rng.below(120))
}

/// Haar round-trips exactly for every shape.
#[test]
fn prop_haar_roundtrip() {
    let mut rng = Rng::new(1001);
    for _ in 0..50 {
        let (r, c) = random_shape(&mut rng);
        let w = Matrix::gauss(r, c, rng.range(0.1, 4.0) as f32, &mut rng);
        let back = haar_rows_inv(&haar_rows(&w), c);
        assert!(w.dist_sq(&back) < 1e-6, "shape {r}x{c}");
    }
}

/// Vector-level Haar round-trip over random lengths — including odd and
/// non-power-of-two sizes, so the `half_len` tail case (leftover sample
/// carried in the low band with a zero high-pass partner) is swept rather
/// than only hit at fixed lengths.
#[test]
fn prop_haar_vec_roundtrip_random_lengths() {
    let mut rng = Rng::new(1010);
    for trial in 0..200 {
        let m = 1 + rng.below(300); // heavy odd / non-pow2 coverage
        let mag = rng.range(0.05, 8.0) as f32;
        let w: Vec<f32> = (0..m).map(|_| mag * rng.gauss() as f32).collect();
        let c = haar_fwd_vec(&w);
        assert_eq!(c.len(), 2 * half_len(m), "trial {trial} m={m}");
        let back = haar_inv_vec(&c, m);
        for (k, (a, b)) in w.iter().zip(&back).enumerate() {
            assert!((a - b).abs() < 1e-5 * (1.0 + a.abs()), "trial {trial} m={m} k={k}");
        }
    }
}

/// Parseval-style energy identity of the [1/2, ±1/2] kernels, random
/// lengths: each even pair contributes (a²+b²)/2 to ‖c‖², and an odd
/// leftover is carried at weight 1 — so
///   ‖c‖² = ‖w_pairs‖²/2 + w_last² (odd m).
/// Energy preservation up to this fixed constant is what makes Haar-domain
/// quantization error comparable across bands.
#[test]
fn prop_haar_vec_energy_identity() {
    let mut rng = Rng::new(1011);
    for trial in 0..200 {
        let m = 1 + rng.below(300);
        let w: Vec<f32> = (0..m).map(|_| rng.gauss() as f32).collect();
        let c = haar_fwd_vec(&w);
        let ec: f64 = c.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let pairs = 2 * (m / 2);
        let mut expect: f64 =
            w[..pairs].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / 2.0;
        if m % 2 == 1 {
            expect += (w[m - 1] as f64) * (w[m - 1] as f64);
        }
        assert!(
            (ec - expect).abs() < 1e-4 * (1.0 + expect),
            "trial {trial} m={m}: {ec} vs {expect}"
        );
    }
}

/// The activation-side transform is the adjoint of the synthesis over
/// random lengths: ⟨B·x, c⟩ = ⟨x, haar_inv(c)⟩ — the identity that makes
/// transform-domain serving (y = C·B·Pᵀx) equal the offline
/// reconstruction.
#[test]
fn prop_haar_act_fwd_is_adjoint_of_synthesis() {
    let mut rng = Rng::new(1012);
    for trial in 0..100 {
        let m = 1 + rng.below(300);
        let j = half_len(m);
        let x: Vec<f32> = (0..m).map(|_| rng.gauss() as f32).collect();
        let c: Vec<f32> = (0..2 * j).map(|_| rng.gauss() as f32).collect();
        let lhs: f64 = haar_act_fwd_vec(&x)
            .iter()
            .zip(&c)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        let rhs: f64 = x
            .iter()
            .zip(&haar_inv_vec(&c, m))
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "trial {trial} m={m}");
    }
}

/// The permutation never increases the pairwise high-pass energy vs the
/// identity ordering (Algorithm 1 minimizes a superset of orderings that
/// includes greedy-from-identity starts).
#[test]
fn prop_permutation_reduces_highpass() {
    let mut rng = Rng::new(1002);
    for trial in 0..30 {
        let (r, c) = random_shape(&mut rng);
        let w = Matrix::gauss(r, c, 1.0, &mut rng);
        let id: Vec<usize> = (0..c).collect();
        let pi = pairing_and_chaining(&w, None, NormKind::L2);
        let e_id = pairwise_highpass_energy(&w, &id);
        let e_pi = pairwise_highpass_energy(&w, &pi);
        assert!(e_pi <= e_id * 1.001, "trial {trial}: {e_pi} > {e_id}");
    }
}

/// Quantization is *near*-idempotent: a second pass over an already
/// binarized matrix moves it by a tiny fraction of its energy. (Exact
/// idempotence does not hold for unbalanced groups: re-estimating μ on a
/// two-level signal with unequal level counts shifts the mean slightly.)
#[test]
fn prop_group_quantizer_near_idempotent() {
    let mut rng = Rng::new(1003);
    for _ in 0..30 {
        let (r, c) = random_shape(&mut rng);
        let spec = GroupSpec {
            group_size: 1 + rng.below(64),
            shared_mean: rng.flip(0.5),
            adaptive_split: false,
        };
        let w = Matrix::gauss(r, c, 1.0, &mut rng);
        let (q1, _) = quantize_matrix(&w, &spec);
        let (q2, _) = quantize_matrix(&q1, &spec);
        let rel = q1.dist_sq(&q2) / q1.frob_norm_sq().max(1e-12);
        assert!(rel < 0.05, "second-pass movement {rel}");
    }
}

/// Packed storage round-trips the dense group binarization exactly and
/// its GEMV matches the dense GEMV, across random shapes/group sizes.
#[test]
fn prop_packed_matches_dense() {
    let mut rng = Rng::new(1004);
    for _ in 0..30 {
        let (r, c) = random_shape(&mut rng);
        let gs = 1 + rng.below(96);
        let w = Matrix::gauss(r, c, rng.range(0.2, 3.0) as f32, &mut rng);
        let packed = PackedBits::pack(&w, gs);
        let dense = packed.dequantize();
        let x: Vec<f32> = (0..c).map(|_| rng.gauss() as f32).collect();
        let mut y = vec![0.0f32; r];
        packed.matvec(&x, &packed.group_sums(&x), &mut y);
        let yd = matvec(&dense, &x);
        for i in 0..r {
            assert!((y[i] - yd[i]).abs() < 1e-3 * (1.0 + yd[i].abs()), "{r}x{c} gs={gs}");
        }
    }
}

/// i8 activation quantize→dequantize round-trip error is ≤ s_tok/2
/// elementwise, across random lengths, scales and degenerate tokens.
#[test]
fn prop_i8_roundtrip_error_below_half_scale() {
    let mut rng = Rng::new(1007);
    for _ in 0..50 {
        let n = 1 + rng.below(300);
        let mag = rng.range(1e-3, 50.0) as f32;
        let x: Vec<f32> = (0..n).map(|_| mag * rng.gauss() as f32).collect();
        let (q, s) = quantize_vec_i8(&x);
        let back = dequantize_vec_i8(&q, s);
        for (a, b) in x.iter().zip(&back) {
            // s/2 in exact arithmetic, plus f32 slack for the reciprocal
            // scale and the scaled product rounding.
            assert!(
                (a - b).abs() <= s * 0.50005 + 1e-12,
                "n={n} mag={mag}: {a} vs {b} (s={s})"
            );
        }
        assert!(q.iter().all(|&v| (-127..=127).contains(&v)));
    }
}

/// W1A8 packed matvec against the true dense product: aggregated over
/// trials, refining the group partition (more groups per row) does not
/// increase the total error — the per-group (α, μ) fit captures more of
/// the weight structure while the activation round-off stays fixed.
#[test]
fn prop_w1a8_error_monotone_in_group_count() {
    let mut rng = Rng::new(1008);
    let mut err_coarse = 0.0f64;
    let mut err_fine = 0.0f64;
    for _ in 0..20 {
        let (r, c) = random_shape(&mut rng);
        let w = Matrix::gauss(r, c, 1.0, &mut rng);
        let x: Vec<f32> = (0..c).map(|_| rng.gauss() as f32).collect();
        let y_true = matvec(&w, &x);
        // One group per row vs many groups per row.
        for (gs, err) in [(c, &mut err_coarse), (8usize, &mut err_fine)] {
            let p = PackedBits::pack(&w, gs);
            let y8 = p.matvec_i8_owned(&x);
            *err += y_true.iter().zip(&y8).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>();
        }
    }
    assert!(
        err_fine <= err_coarse * 1.001 + 1e-9,
        "finer groups must not increase W1A8 error: fine {err_fine} vs coarse {err_coarse}"
    );
}

/// W1A8 matvec vs the f32 packed matvec across random shapes and group
/// sizes (including non-multiples of 64): within the analytic
/// activation-round-off bound, and the GEMM path bit-equals the GEMV
/// path per token.
#[test]
fn prop_w1a8_matches_f32_packed_random_groups() {
    let mut rng = Rng::new(1009);
    for _ in 0..30 {
        let (r, c) = random_shape(&mut rng);
        let gs = 1 + rng.below(100); // includes non-multiples of 64
        let w = Matrix::gauss(r, c, rng.range(0.2, 3.0) as f32, &mut rng);
        let x: Vec<f32> = (0..c).map(|_| rng.gauss() as f32).collect();
        let p = PackedBits::pack(&w, gs);
        let deq = p.dequantize();
        let mut y32 = vec![0.0f32; r];
        p.matvec(&x, &p.group_sums(&x), &mut y32);
        let act = p.quantize_act(&x);
        let mut y8 = vec![0.0f32; r];
        p.matvec_i8(&act, &mut y8);
        for i in 0..r {
            let abs_row: f32 = deq.row(i).iter().map(|v| v.abs()).sum();
            let bound = 0.5 * act.scale * abs_row * 1.001 + 1e-4;
            assert!(
                (y32[i] - y8[i]).abs() <= bound,
                "{r}x{c} gs={gs} row {i}: {} vs {}",
                y32[i],
                y8[i]
            );
        }
        // Single-column GEMM equals the GEMV bit-for-bit.
        let xm = Matrix::from_vec(c, 1, x.clone());
        let ym = p.matmul_i8(&xm);
        for i in 0..r {
            assert_eq!(ym.at(i, 0), y8[i], "{r}x{c} gs={gs} row {i}");
        }
    }
}

/// Bit-sliced popcount kernel ≡ trailing_zeros extraction kernel,
/// BIT-EXACTLY, over random shapes, random group sizes (non-multiples of
/// 64 included), random residual-plane orders, and activation regimes
/// including saturated q = ±127 tokens — GEMV and GEMM both. The sliced
/// kernel is the hot path; the extraction kernel is the retained
/// reference (like `matvec_per_bit` for f32), so this is the wall that
/// lets the hot path evolve without silently changing results.
#[test]
fn prop_bit_sliced_kernel_equals_extraction_bit_exact() {
    let mut rng = Rng::new(1011);
    for trial in 0..40 {
        let (r, c) = random_shape(&mut rng);
        let gs = 1 + rng.below(100);
        let order = 1 + rng.below(3); // random residual-plane chains
        let w = Matrix::gauss(r, c, rng.range(0.2, 3.0) as f32, &mut rng);
        let p = PackedBits::pack_residual(&w, gs, order, 0.0);
        // Three activation regimes: gaussian, saturating (every q hits
        // ±127), and sparse-with-zeros.
        let regime = trial % 3;
        let x: Vec<f32> = (0..c)
            .map(|j| match regime {
                0 => rng.gauss() as f32,
                1 => {
                    if (j + trial) % 2 == 0 {
                        5.0
                    } else {
                        -5.0
                    }
                }
                _ => {
                    if rng.flip(0.5) {
                        0.0
                    } else {
                        rng.gauss() as f32
                    }
                }
            })
            .collect();
        let act = p.quantize_act(&x);
        if regime == 1 {
            assert!(act.q.iter().all(|&v| v == 127 || v == -127), "trial {trial}");
        }
        let mut y_sliced = vec![0.0f32; r];
        let mut y_extract = vec![0.0f32; r];
        p.matvec_i8(&act, &mut y_sliced);
        p.matvec_i8_extract(&act, &mut y_extract);
        assert_eq!(y_sliced, y_extract, "trial {trial} {r}x{c} gs={gs} order={order} GEMV");
        let n = 1 + rng.below(6);
        let xm = Matrix::gauss(c, n, rng.range(0.2, 2.0) as f32, &mut rng);
        let g_sliced = p.matmul_i8(&xm);
        let g_extract = p.matmul_i8_extract(&xm);
        assert_eq!(
            g_sliced.data, g_extract.data,
            "trial {trial} {r}x{c} gs={gs} order={order} GEMM"
        );
    }
}

/// The 70 = 64+6 tail shape, pinned explicitly (one full sign word plus a
/// 6-bit tail word) across every entry point of the sliced kernel,
/// including the threaded GEMM at threads ∈ {1, 4} — sized PAST the
/// parallel work threshold so the threads=4 run genuinely exercises the
/// row fan-out (asserted, so a threshold retune can't quietly make this
/// vacuous).
#[test]
fn prop_bit_sliced_tail_shapes_and_thread_invariance() {
    use hbvla::quant::packed::PAR_WORK_MIN;
    let mut rng = Rng::new(1012);
    let (rows, n, order) = (128usize, 32usize, 2usize);
    for &cols in &[70usize, 64, 65, 128, 129] {
        assert!(
            (rows * cols * n * order) as f64 >= PAR_WORK_MIN,
            "cols={cols}: test no longer crosses the parallel threshold"
        );
        let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
        let p = PackedBits::pack_residual(&w, 64, order, 0.0);
        let x = Matrix::gauss(cols, n, 1.0, &mut rng);
        let a1 = p.matmul_i8_mt(&x, 1);
        let a4 = p.matmul_i8_mt(&x, 4);
        let e1 = p.matmul_i8_extract(&x);
        assert_eq!(a1.data, a4.data, "cols={cols} thread variance");
        assert_eq!(a1.data, e1.data, "cols={cols} sliced vs extraction");
    }
}

/// Every wide lane this machine can run (scalar, wide4, and avx2 when
/// detected) produces BIT-IDENTICAL output to the trailing_zeros
/// extraction reference — same sweep as the sliced-vs-extraction wall:
/// random shapes, random group sizes, random residual-plane orders, and
/// the saturated q = ±127 regime where popcount totals are largest. The
/// lane is forced explicitly so the test covers lanes the runtime
/// dispatcher would not pick on this machine.
#[test]
fn prop_forced_lane_kernels_equal_extraction_bit_exact() {
    let mut rng = Rng::new(1013);
    let lanes = SimdLane::available();
    for trial in 0..25 {
        let (r, c) = random_shape(&mut rng);
        let gs = 1 + rng.below(100);
        let order = 1 + rng.below(3);
        let w = Matrix::gauss(r, c, rng.range(0.2, 3.0) as f32, &mut rng);
        let p = PackedBits::pack_residual(&w, gs, order, 0.0);
        let saturate = trial % 2 == 1;
        let x: Vec<f32> = (0..c)
            .map(|j| {
                if saturate {
                    if (j + trial) % 2 == 0 {
                        5.0
                    } else {
                        -5.0
                    }
                } else {
                    rng.gauss() as f32
                }
            })
            .collect();
        let act = p.quantize_act(&x);
        let mut y_extract = vec![0.0f32; r];
        p.matvec_i8_extract(&act, &mut y_extract);
        let n = 1 + rng.below(6);
        let xm = Matrix::gauss(c, n, rng.range(0.2, 2.0) as f32, &mut rng);
        let g_extract = p.matmul_i8_extract(&xm);
        for &lane in &lanes {
            let mut y = vec![0.0f32; r];
            p.matvec_i8_lane(&act, &mut y, 1, lane);
            assert_eq!(
                y,
                y_extract,
                "trial {trial} {r}x{c} gs={gs} order={order} GEMV lane={}",
                lane.label()
            );
            for threads in [1usize, 4] {
                let g = p.matmul_i8_lane(&xm, threads, lane);
                assert_eq!(
                    g.data,
                    g_extract.data,
                    "trial {trial} {r}x{c} gs={gs} order={order} GEMM lane={} threads={threads}",
                    lane.label()
                );
            }
        }
    }
}

/// The 70 = 64+6 tail shape per forced lane: one full sign word plus a
/// 6-bit tail word is exactly where a wide accumulator loop can over-read
/// or mis-mask, so every lane is pinned against extraction on the word
/// boundary family, at threads ∈ {1, 4}.
#[test]
fn prop_forced_lane_tail_words_bit_exact() {
    let mut rng = Rng::new(1014);
    let (rows, n, order) = (96usize, 8usize, 2usize);
    for &cols in &[70usize, 64, 65, 127, 128, 129, 257] {
        let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
        let p = PackedBits::pack_residual(&w, 64, order, 0.0);
        let xm = Matrix::gauss(cols, n, 1.0, &mut rng);
        let reference = p.matmul_i8_extract(&xm);
        let x: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
        let act = p.quantize_act(&x);
        let mut y_ref = vec![0.0f32; rows];
        p.matvec_i8_extract(&act, &mut y_ref);
        for lane in SimdLane::available() {
            for threads in [1usize, 4] {
                let g = p.matmul_i8_lane(&xm, threads, lane);
                assert_eq!(
                    g.data,
                    reference.data,
                    "cols={cols} lane={} threads={threads}",
                    lane.label()
                );
            }
            let mut y = vec![0.0f32; rows];
            p.matvec_i8_lane(&act, &mut y, 1, lane);
            assert_eq!(y, y_ref, "cols={cols} lane={} GEMV", lane.label());
        }
    }
}

/// Every method, on every random layer: finite output, correct shape,
/// strictly-positive bit accounting, error strictly below "all zeros".
#[test]
fn prop_all_methods_sane_on_random_layers() {
    let mut rng = Rng::new(1005);
    for trial in 0..12 {
        let (r, c) = random_shape(&mut rng);
        let w = Matrix::gauss(r, c, rng.range(0.2, 2.0) as f32, &mut rng);
        let x = Matrix::gauss(c, 3 * c, 1.0, &mut rng);
        let mut h = gram(&x);
        h.scale(1.0 / (3 * c) as f32);
        let calib = CalibData::from_hessian(h, Component::Language);
        for method in paper_methods() {
            let q = method.quantize(&w, &calib);
            assert_eq!((q.w_hat.rows, q.w_hat.cols), (r, c), "{} trial {trial}", method.name());
            assert!(q.w_hat.is_finite(), "{} trial {trial}", method.name());
            assert!(q.rel_frob_err < 1.0, "{} err {}", method.name(), q.rel_frob_err);
            assert!(q.stats.bits_per_weight() > 0.5, "{}", method.name());
        }
    }
}

/// Orthogonality of the transform chain: permutation + Haar preserve the
/// Frobenius norm (Eq. 13's geometry-preservation claim).
#[test]
fn prop_transform_chain_is_isometric() {
    let mut rng = Rng::new(1006);
    for _ in 0..30 {
        let (r, c) = random_shape(&mut rng);
        if c % 2 != 0 {
            continue; // exact isometry holds for even lengths
        }
        let w = Matrix::gauss(r, c, 1.0, &mut rng);
        let pi = pairing_and_chaining(&w, Some(8), NormKind::L2);
        let wp = hbvla::quant::permute::permute_cols(&w, &pi);
        let u = haar_rows(&wp);
        // Our Haar uses the [.5,.5]/[.5,−.5] kernels: ‖U‖² = ‖W‖²/2 exactly
        // for even lengths (the 2×2 block has singular values 1/√2·√2 …
        // verify the constant empirically rather than assuming).
        let ratio = u.frob_norm_sq() / w.frob_norm_sq();
        assert!((ratio - 0.5).abs() < 1e-3, "ratio {ratio}");
    }
}
