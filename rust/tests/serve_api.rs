//! Serving-API integration: quantize → register → serve with per-request
//! variant routing, true batched packed inference, and typed errors.
//!
//! The acceptance property: a request submitted with variant
//! `hbvla-packed` is served by the packed model through the multi-token
//! packed GEMM batch path, bit-identically to that model's own
//! single-request forward and within kernel tolerance of its dense twin —
//! and nothing on the public serving surface panics, even on a stopped
//! server.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use hbvla::coordinator::{
    quantize_into_registry, register_a8_variant, AdmissionControl, ModelRegistry, PolicyServer,
    ServeConfig, ServeError, ServeRequest,
};
use hbvla::methods::traits::Component;
use hbvla::methods::HbVla;
use hbvla::model::{HeadKind, MiniVla, VlaConfig};
use hbvla::sim::observe::{observe, ObsParams, Observation};
use hbvla::sim::tasks::libero_suite;
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

/// Tiny chunk-head checkpoint with real head weights.
fn base_model() -> MiniVla {
    let mut m = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
    let mut rng = Rng::new(0xF00D);
    let (hr, hc) = m.store.dims("head.main");
    m.store.set("head.main", Matrix::gauss(hr, hc, 0.1, &mut rng));
    m
}

fn sample_obs(model: &MiniVla, seed: u64) -> Observation {
    let task = &libero_suite("object")[0];
    let mut rng = Rng::new(seed);
    let scene = task.instantiate(&mut rng);
    observe(&scene, task.stages[0].instr(), 100, model, &ObsParams::clean(), &mut rng)
}

#[test]
fn quantize_register_serve_batched_packed_parity() {
    let base = base_model();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    // Quantize every component (heads too) so the full served forward —
    // trunk AND decode — runs on packed kernels.
    let calib = HashMap::new();
    let comps = [Component::Vision, Component::Language, Component::ActionHead];
    let rep = quantize_into_registry(
        &registry,
        "hbvla-packed",
        &base,
        &calib,
        &HbVla::new(),
        &comps,
        2,
    )
    .unwrap();
    assert!(rep.packed_layers > 0, "{rep:?}");
    let served = registry.get("hbvla-packed").expect("registered variant");
    assert!(served.store.packed_layer_count() > 0);
    let mut twin = (*served).clone();
    assert!(twin.store.dequantize_all() > 0);

    // max_batch equals the burst size so the batch closes on count once
    // every submit lands; the long max_wait only covers a descheduled
    // submitter, keeping the coalescing assertion deterministic on CI.
    let server = PolicyServer::start(
        Arc::clone(&registry),
        ServeConfig { workers: 1, max_batch: 6, max_wait: Duration::from_millis(500), ..Default::default() },
    );
    let obs: Vec<Observation> = (0..6).map(|k| sample_obs(&base, 50 + k)).collect();
    // Async burst: the router coalesces these into multi-request batches,
    // so the packed variant executes the multi-token packed GEMM.
    let handles: Vec<_> = obs
        .iter()
        .map(|o| {
            server
                .submit_async(ServeRequest::new(o.clone()).with_variant("hbvla-packed"))
                .unwrap()
        })
        .collect();
    let responses: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    assert!(server.batch_stats().max_recent() >= 2, "requests never coalesced");

    for (o, rsp) in obs.iter().zip(&responses) {
        assert_eq!(rsp.variant_served, "hbvla-packed");
        // Bit-identical to the packed model's own single-request forward:
        // batching must not change any request's answer.
        let feat = served.features(&o.visual_raw, o.instr_id, &o.proprio, &mut None);
        let expect = served.decode(&feat, &mut Rng::new(0));
        assert_eq!(rsp.actions, expect, "batched serve diverged from single packed forward");
        // Within kernel tolerance of the dense twin (deploy parity).
        let tf = twin.features(&o.visual_raw, o.instr_id, &o.proprio, &mut None);
        let texp = twin.decode(&tf, &mut Rng::new(0));
        assert_eq!(rsp.actions.len(), texp.len());
        for (ca, cb) in rsp.actions.iter().zip(&texp) {
            for (a, b) in ca.iter().zip(cb) {
                assert!((a - b).abs() < 1e-2 * (1.0 + b.abs()), "packed {a} vs dense twin {b}");
            }
        }
    }
    let per = server.variant_stats();
    assert_eq!(per["hbvla-packed"].requests, 6);
    server.shutdown();
}

#[test]
fn mixed_w1a32_w1a8_batch_each_request_bit_identical() {
    // One coalesced batch holding BOTH `hbvla-packed` (W1A32) and
    // `hbvla-packed-a8` (W1A8) requests: the router splits the batch by
    // variant, each group runs its own batched forward, and every
    // response must be bit-identical to its own model's sequential
    // forward with `variant_served` naming the right twin.
    let base = base_model();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    let calib = HashMap::new();
    let comps = [Component::Vision, Component::Language, Component::ActionHead];
    quantize_into_registry(&registry, "hbvla-packed", &base, &calib, &HbVla::new(), &comps, 2)
        .unwrap();
    let a8_name = register_a8_variant(&registry, "hbvla-packed").unwrap();
    assert_eq!(a8_name, "hbvla-packed-a8");
    let m32 = registry.get("hbvla-packed").unwrap();
    let m8 = registry.get("hbvla-packed-a8").unwrap();
    assert_eq!(m8.store.act_precision(), hbvla::model::ActPrecision::Int8);

    let server = PolicyServer::start(
        Arc::clone(&registry),
        ServeConfig { workers: 1, max_batch: 6, max_wait: Duration::from_millis(500), ..Default::default() },
    );
    let obs: Vec<Observation> = (0..6).map(|k| sample_obs(&base, 80 + k)).collect();
    // Interleave the two variants inside one burst.
    let names = ["hbvla-packed", "hbvla-packed-a8"];
    let handles: Vec<_> = obs
        .iter()
        .enumerate()
        .map(|(k, o)| {
            server
                .submit_async(ServeRequest::new(o.clone()).with_variant(names[k % 2]))
                .unwrap()
        })
        .collect();
    let responses: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    assert!(server.batch_stats().max_recent() >= 2, "requests never coalesced");

    for (k, (o, rsp)) in obs.iter().zip(&responses).enumerate() {
        let expect_variant = names[k % 2];
        assert_eq!(rsp.variant_served, expect_variant, "request {k}");
        let model = if k % 2 == 0 { &m32 } else { &m8 };
        let feat = model.features(&o.visual_raw, o.instr_id, &o.proprio, &mut None);
        let expect = model.decode(&feat, &mut Rng::new(0));
        assert_eq!(
            rsp.actions, expect,
            "request {k} ({expect_variant}) diverged from its own sequential forward"
        );
    }
    let per = server.variant_stats();
    assert_eq!(per["hbvla-packed"].requests, 3);
    assert_eq!(per["hbvla-packed-a8"].requests, 3);
    server.shutdown();
}

#[test]
fn deadline_aware_admission_sheds_at_submit_not_dispatch() {
    // ROADMAP follow-on landed: under queue pressure, a deadline the
    // observed service rate cannot meet is refused AT SUBMIT with the
    // typed Overloaded error — it never queues, never reaches dispatch
    // triage, and never panics.
    let base = base_model();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    let server = PolicyServer::start(
        Arc::clone(&registry),
        ServeConfig {
            workers: 1,
            max_batch: 64,
            max_wait: Duration::from_millis(500),
            admission: AdmissionControl::DeadlineAware { min_samples: 4 },
            ..Default::default()
        },
    );
    let obs = sample_obs(&base, 21);
    // Warm the compute statistics (cold stats never shed).
    for _ in 0..4 {
        server.submit(ServeRequest::new(obs.clone())).unwrap();
    }
    // Hold a batch window open so the queue is observably non-empty…
    let pending = server.submit_async(ServeRequest::new(obs.clone())).unwrap();
    assert!(server.queue_depth() >= 1);
    // …then an impossible deadline behind it is shed with Overloaded.
    let err = server
        .submit(ServeRequest::new(obs.clone()).with_deadline(Duration::from_nanos(1)))
        .unwrap_err();
    match err {
        ServeError::Overloaded { queue_depth, estimated_wait, retry_after_us } => {
            assert!(queue_depth >= 1);
            // The retry hint is the predicted overshoot past the deadline:
            // at least 1µs (it IS overloaded), never more than the whole
            // estimated queue wait (the deadline is non-negative).
            assert!(retry_after_us >= 1, "retry hint must be actionable");
            assert!(
                u128::from(retry_after_us) <= estimated_wait.as_micros() + 1,
                "retry_after_us {} exceeds estimated wait {:?}",
                retry_after_us,
                estimated_wait
            );
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    // A generous deadline is still admitted and served from the same queue.
    let lax = server
        .submit_async(ServeRequest::new(obs.clone()).with_deadline(Duration::from_secs(30)))
        .unwrap();
    pending.wait().unwrap();
    lax.wait().unwrap();
    let per = server.variant_stats();
    assert_eq!(per["dense"].admission_sheds, 1);
    assert_eq!(per["dense"].deadline_misses, 0, "shed at submit, not triaged at dispatch");
    server.shutdown();
}

#[test]
fn serving_surface_errors_instead_of_panicking() {
    let base = base_model();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    let server = PolicyServer::start(Arc::clone(&registry), ServeConfig::default());
    let obs = sample_obs(&base, 7);

    // Unknown variant: typed error at submit time.
    let err =
        server.submit(ServeRequest::new(obs.clone()).with_variant("not-registered")).unwrap_err();
    assert!(matches!(err, ServeError::UnknownVariant(_)));

    // Stopped server: typed error, idempotent shutdown, no panic.
    server.submit(ServeRequest::new(obs.clone())).unwrap();
    server.shutdown();
    assert_eq!(server.submit(ServeRequest::new(obs.clone())).unwrap_err(), ServeError::Stopped);
    assert!(server.submit_async(ServeRequest::new(obs)).is_err());
    server.shutdown();
}

#[test]
fn empty_registry_reports_no_variants() {
    let registry = Arc::new(ModelRegistry::new());
    let server = PolicyServer::start(Arc::clone(&registry), ServeConfig::default());
    // Can't build an Observation without a model, so register late and use
    // the default-variant resolution path against the empty registry.
    let base = base_model();
    let obs = sample_obs(&base, 3);
    assert_eq!(server.submit(ServeRequest::new(obs.clone())).unwrap_err(), ServeError::NoVariants);
    // Live registration: the running server picks the variant up.
    registry.register("dense", Arc::new(base)).unwrap();
    let rsp = server.submit(ServeRequest::new(obs)).unwrap();
    assert_eq!(rsp.variant_served, "dense");
    server.shutdown();
}
