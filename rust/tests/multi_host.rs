//! Multi-host serving integration: the router front door over N wire
//! hosts must be invisible to correctness.
//!
//! The non-negotiable invariant (ISSUE PR 9): actions served through the
//! router are bit-identical to a direct in-process forward for EVERY
//! host count — the front door owns the seq stream, so WHICH host serves
//! a request never changes its actions. On top of that: the wire decoder
//! is total (typed errors, never panics), a lost host fails in-flight
//! requests with typed errors and re-homes its variants onto survivors
//! with zero hangs, and the fleet harness produces identical reports
//! whether requests go through function calls or TCP frames.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hbvla::coordinator::router::LocalCluster;
use hbvla::coordinator::wire::{decode_frame, encode_frame, Frame, FrameReader};
use hbvla::coordinator::{
    quantize_into_registry, ModelRegistry, PolicyServer, Router, RouterConfig, ServeConfig,
    ServeError, ServeRequest, ServeResponse, VariantSelector, WireError, WireHost,
};
use hbvla::fleet::{run_fleet, run_fleet_on, Drill, FleetConfig, FleetError, FleetReport};
use hbvla::methods::traits::Component;
use hbvla::methods::HbVla;
use hbvla::model::{HeadKind, MiniVla, VlaConfig};
use hbvla::sim::observe::{observe, ObsParams, Observation};
use hbvla::sim::tasks::libero_suite;
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

/// Tiny chunk-head checkpoint with real head weights plus its packed
/// 1-bit commit — the minimal two-variant menu, mirroring tests/fleet.rs.
fn fleet_registry() -> Arc<ModelRegistry> {
    let mut base = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
    let mut rng = Rng::new(0xF1EE7);
    let (hr, hc) = base.store.dims("head.main");
    base.store.set("head.main", Matrix::gauss(hr, hc, 0.1, &mut rng));
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    let comps = [Component::Vision, Component::Language, Component::ActionHead];
    let rep = quantize_into_registry(
        &registry,
        "hbvla-packed",
        &base,
        &HashMap::new(),
        &HbVla::new(),
        &comps,
        2,
    )
    .unwrap();
    assert!(rep.packed_layers > 0, "{rep:?}");
    registry
}

fn sample_obs(model: &MiniVla, seed: u64) -> Observation {
    let task = &libero_suite("object")[0];
    let mut rng = Rng::new(seed);
    let scene = task.instantiate(&mut rng);
    observe(&scene, task.stages[0].instr(), 100, model, &ObsParams::clean(), &mut rng)
}

fn serve_cfg(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        ..Default::default()
    }
}

fn assert_bit_identical(direct: &ServeResponse, routed: &ServeResponse, label: &str) {
    assert_eq!(direct.variant_served, routed.variant_served, "{label}: variant moved");
    assert_eq!(direct.actions.len(), routed.actions.len(), "{label}: chunk length moved");
    for (da, ra) in direct.actions.iter().zip(&routed.actions) {
        assert_eq!(da.len(), ra.len());
        for (x, y) in da.iter().zip(ra) {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: actions diverged");
        }
    }
}

/// Every submit is answered OK or lands in exactly one typed error
/// counter — nothing silent, nothing lost (same closure as tests/fleet.rs).
fn assert_accounting_closed(report: &FleetReport) {
    let mut total_ok = 0;
    for row in &report.rows {
        assert_eq!(
            row.submits,
            row.responses_ok + row.admission_sheds + row.deadline_misses + row.errors,
            "accounting leak in variant '{}': {row:?}",
            row.variant
        );
        total_ok += row.responses_ok;
    }
    assert_eq!(total_ok, report.total_responses);
    assert_eq!(report.rows.iter().map(|r| r.robots).sum::<usize>(), report.robots);
}

// ------------------------------------------------------------- parity

#[test]
fn routed_actions_bit_identical_to_direct_for_hosts_1_2_4() {
    let registry = fleet_registry();
    let model = registry.get("dense").unwrap();
    let requests: Vec<ServeRequest> = (0..8)
        .map(|i| {
            let v = if i % 2 == 0 { "dense" } else { "hbvla-packed" };
            ServeRequest::new(sample_obs(&model, 100 + i)).with_variant(v)
        })
        .collect();

    let server = PolicyServer::start(Arc::clone(&registry), serve_cfg(2));
    let direct: Vec<ServeResponse> =
        requests.iter().map(|r| server.submit(r.clone()).unwrap()).collect();
    server.shutdown();

    for hosts in [1usize, 2, 4] {
        let cluster =
            LocalCluster::spawn(Arc::clone(&registry), serve_cfg(2), hosts, RouterConfig::default())
                .unwrap();
        for (i, req) in requests.iter().enumerate() {
            let routed = cluster.router.submit(req.clone()).unwrap();
            assert_bit_identical(&direct[i], &routed, &format!("hosts={hosts} request={i}"));
        }
        cluster.shutdown();
    }
}

#[test]
fn router_seq_stream_pins_stochastic_heads_across_host_counts() {
    // A Diffusion head decodes through a noise stream keyed by request
    // seq — the one place placement COULD leak into actions. The router
    // mints the seq stream itself (one global counter), so host count
    // must not move a single bit.
    let model = MiniVla::new(VlaConfig::tiny(HeadKind::Diffusion));
    let obs = sample_obs(&model, 1);
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(model)).unwrap();

    let server = PolicyServer::start(Arc::clone(&registry), serve_cfg(1));
    let direct: Vec<ServeResponse> =
        (0..6).map(|_| server.submit(ServeRequest::new(obs.clone())).unwrap()).collect();
    server.shutdown();

    for hosts in [1usize, 2] {
        let cluster =
            LocalCluster::spawn(Arc::clone(&registry), serve_cfg(1), hosts, RouterConfig::default())
                .unwrap();
        for (i, d) in direct.iter().enumerate() {
            let routed = cluster.router.submit(ServeRequest::new(obs.clone())).unwrap();
            assert_bit_identical(d, &routed, &format!("diffusion hosts={hosts} seq={i}"));
        }
        cluster.shutdown();
    }
}

// ------------------------------------------------------- wire protocol

#[test]
fn request_frames_round_trip_including_hostile_variant_names() {
    let hostile = [
        "plain",
        "evil\"quote",
        "new\nline",
        "back\\slash",
        "nul\0byte",
        "ünïcødé-名前-🦾",
        "",
    ];
    let mut rng = Rng::new(0xB17E5);
    for trial in 0..64u64 {
        let rows = rng.below(5) + 1;
        let cols = rng.below(7) + 1;
        let obs = Observation {
            visual_raw: Matrix::gauss(rows, cols, 1.0, &mut rng),
            instr_id: rng.below(1 << 20),
            proprio: (0..rng.below(9)).map(|_| rng.gauss() as f32).collect(),
        };
        let mut req = ServeRequest::new(obs);
        if trial % 3 != 0 {
            req = req.with_variant(hostile[rng.below(hostile.len())]);
        }
        if trial % 2 == 0 {
            req = req.with_deadline(Duration::from_micros(rng.next_u64() % 1_000_000));
        }
        let frame = Frame::Request { id: rng.next_u64(), seq: rng.next_u64(), req: req.clone() };

        // Round-trip the body directly, then again through FrameReader
        // fed one byte at a time (worst-case fragmentation).
        let body = encode_frame(&frame);
        for pass in 0..2 {
            let decoded = if pass == 0 {
                decode_frame(&body).unwrap()
            } else {
                let mut fr = FrameReader::new();
                fr.extend(&(body.len() as u32).to_le_bytes());
                let mut out = None;
                for &b in &body {
                    assert!(out.is_none(), "frame completed before the last byte");
                    fr.extend(&[b]);
                    out = fr.next_frame().unwrap();
                }
                out.expect("frame incomplete after the last byte")
            };
            let Frame::Request { id, seq, req: got } = decoded else {
                panic!("trial {trial}: wrong frame kind");
            };
            let Frame::Request { id: want_id, seq: want_seq, req: want } = &frame else {
                unreachable!()
            };
            assert_eq!(id, *want_id);
            assert_eq!(seq, *want_seq);
            assert_eq!(got.variant, want.variant, "trial {trial}: variant selector moved");
            assert_eq!(got.deadline, want.deadline, "trial {trial}: deadline moved");
            assert_eq!(got.obs.instr_id, want.obs.instr_id);
            assert_eq!(got.obs.proprio.len(), want.obs.proprio.len());
            for (x, y) in got.obs.proprio.iter().zip(&want.obs.proprio) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            assert_eq!(got.obs.visual_raw.rows, want.obs.visual_raw.rows);
            assert_eq!(got.obs.visual_raw.cols, want.obs.visual_raw.cols);
            for (x, y) in got.obs.visual_raw.data.iter().zip(&want.obs.visual_raw.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}

#[test]
fn malformed_frames_yield_typed_errors_never_panics() {
    let registry = fleet_registry();
    let model = registry.get("dense").unwrap();
    let req = ServeRequest::new(sample_obs(&model, 2)).with_variant("hbvla-packed");
    let body = encode_frame(&Frame::Request { id: 7, seq: 9, req });

    // Every possible truncation errs — no prefix of a Request body is a
    // valid frame, and decode must say so with a typed error.
    for cut in 0..body.len() {
        assert!(
            decode_frame(&body[..cut]).is_err(),
            "truncated body of {cut}/{} bytes decoded",
            body.len()
        );
    }
    // Trailing garbage after a complete frame is typed, not ignored.
    let mut padded = body.clone();
    padded.push(0);
    assert!(matches!(decode_frame(&padded), Err(WireError::TrailingBytes { .. })));
    // Unknown tag byte.
    assert!(matches!(decode_frame(&[0xAA]), Err(WireError::BadTag(0xAA))));
    assert!(matches!(decode_frame(&[]), Err(WireError::Truncated { .. })));
    // An oversize length prefix is rejected before any allocation.
    let mut fr = FrameReader::new();
    fr.extend(&u32::MAX.to_le_bytes());
    assert!(matches!(fr.next_frame(), Err(WireError::Oversize { .. })));
    // Pure fuzz: random bytes decode to SOME result without panicking.
    let mut rng = Rng::new(0xFADED);
    for _ in 0..512 {
        let n = rng.below(96);
        let bytes: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let _ = decode_frame(&bytes);
    }
}

#[test]
fn garbage_connection_is_dropped_but_host_serves_on() {
    let registry = fleet_registry();
    let host = WireHost::spawn(Arc::clone(&registry), serve_cfg(1), "127.0.0.1:0").unwrap();
    let addr = host.addr();

    // Two hostile clients: an oversize length prefix, then a bad-tag
    // body. Each must get ITS connection dropped (read drains the
    // greeting Health frame, then EOF) without wedging the host.
    let oversize = u32::MAX.to_le_bytes();
    let attacks: [&[u8]; 2] = [
        &oversize,
        &[5, 0, 0, 0, 0xAA, 1, 2, 3, 4], // 5-byte body, unknown tag 0xAA
    ];
    for attack in attacks {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(attack).unwrap();
        s.flush().unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut buf = [0u8; 4096];
        loop {
            match s.read(&mut buf) {
                Ok(0) => break, // clean FIN from the host: connection dropped
                Ok(_) => {}     // greeting Health frame bytes
                Err(e)
                    if e.kind() == std::io::ErrorKind::ConnectionReset
                        || e.kind() == std::io::ErrorKind::ConnectionAborted =>
                {
                    break
                }
                Err(e) => panic!("host never closed the hostile connection: {e}"),
            }
        }
    }

    // A fresh well-formed client still gets served.
    let model = registry.get("dense").unwrap();
    let router = Router::connect(&[addr.to_string()], RouterConfig::default()).unwrap();
    let rsp = router
        .submit(ServeRequest::new(sample_obs(&model, 3)).with_variant("dense"))
        .unwrap();
    assert_eq!(rsp.variant_served, "dense");
    assert!(!rsp.actions.is_empty());
    router.shutdown();
    host.shutdown();
}

// ---------------------------------------------------------- host loss

#[test]
fn host_loss_mid_flight_fails_typed_and_rehomes() {
    let registry = fleet_registry();
    let model = registry.get("dense").unwrap();
    let obs = sample_obs(&model, 5);
    let cluster =
        LocalCluster::spawn(Arc::clone(&registry), serve_cfg(2), 2, RouterConfig::default())
            .unwrap();

    // A wave in flight across both variants, then the drill primitive.
    let handles: Vec<_> = (0..16)
        .map(|i| {
            let v = if i % 2 == 0 { "dense" } else { "hbvla-packed" };
            cluster.router.submit_async(ServeRequest::new(obs.clone()).with_variant(v)).unwrap()
        })
        .collect();
    let killed = cluster.kill_host();
    assert!(killed.is_some(), "kill_host refused with 2 live hosts");

    // Zero hangs: every handle resolves; each failure is typed.
    let (mut ok, mut lost) = (0, 0);
    for h in handles {
        match h.wait() {
            Ok(rsp) => {
                assert!(!rsp.actions.is_empty());
                ok += 1;
            }
            Err(ServeError::WorkerDropped) | Err(ServeError::Stopped) => lost += 1,
            Err(e) => panic!("untyped/unexpected failure after host loss: {e:?}"),
        }
    }
    assert_eq!(ok + lost, 16);

    // The router notices the dead connection…
    let deadline = Instant::now() + Duration::from_secs(5);
    while cluster.router.live_hosts() > 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(cluster.router.live_hosts(), 1, "router never noticed the dead host");
    assert_eq!(cluster.live_hosts(), 1);

    // …and every variant re-homes onto the survivor along the probe order.
    for v in ["dense", "hbvla-packed"] {
        let rsp = cluster.router.submit(ServeRequest::new(obs.clone()).with_variant(v)).unwrap();
        assert_eq!(rsp.variant_served, v, "variant '{v}' did not re-home");
    }
    cluster.shutdown();
}

// ------------------------------------------------------ fleet over wire

#[test]
fn fleet_reports_identical_across_direct_and_routed_transports() {
    let registry = fleet_registry();
    let cfg = FleetConfig {
        robots: 6,
        horizon: 12,
        variants: vec!["dense".into(), "hbvla-packed".into()],
        seed: 47,
        ..Default::default()
    };

    let server = PolicyServer::start(Arc::clone(&registry), serve_cfg(2));
    let direct = run_fleet(&registry, &server, &cfg, &ObsParams::clean()).unwrap();
    server.shutdown();

    let cluster =
        LocalCluster::spawn(Arc::clone(&registry), serve_cfg(2), 2, RouterConfig::default())
            .unwrap();
    let routed = run_fleet_on(&registry, &cluster, &cfg, &ObsParams::clean()).unwrap();
    cluster.shutdown();

    assert_accounting_closed(&direct);
    assert_accounting_closed(&routed);
    assert_eq!(direct.total_responses, routed.total_responses);
    assert_eq!(direct.rows.len(), routed.rows.len());
    for (a, b) in direct.rows.iter().zip(&routed.rows) {
        assert_eq!(a.variant, b.variant);
        // Same per-robot trajectories bit-for-bit => same variant digest,
        // whether requests were function calls or TCP frames.
        assert_eq!(a.digest, b.digest, "transport changed '{}' trajectories", a.variant);
        assert_eq!(a.successes, b.successes);
        assert_eq!(a.submits, b.submits);
        assert_eq!(a.responses_ok, b.responses_ok);
        assert_eq!((b.errors, b.dropped, b.admission_sheds), (0, 0, 0));
    }
}

#[test]
fn host_loss_drill_degrades_gracefully() {
    let registry = fleet_registry();
    let cluster =
        LocalCluster::spawn(Arc::clone(&registry), serve_cfg(2), 2, RouterConfig::default())
            .unwrap();
    let cfg = FleetConfig {
        robots: 8,
        horizon: 12,
        variants: vec!["dense".into(), "hbvla-packed".into()],
        seed: 53,
        drills: vec![Drill::HostLoss],
        ..Default::default()
    };
    let report = run_fleet_on(&registry, &cluster, &cfg, &ObsParams::clean()).unwrap();
    cluster.shutdown();

    assert_accounting_closed(&report);
    let d = &report.drill_report;
    assert_eq!(d.hosts_before_loss, 2, "{d:?}");
    assert_eq!(d.hosts_after_loss, 1, "{d:?}");
    assert!(d.host_killed.is_some(), "{d:?}");
    // Graceful degradation: requests caught on the dying host fail typed
    // and are retried onto the survivor — every robot still finishes.
    for row in &report.rows {
        assert_eq!(row.dropped, 0, "variant '{}' dropped robots: {row:?}", row.variant);
        assert!(row.responses_ok > 0);
        assert_eq!(row.submits, row.responses_ok + row.errors, "{row:?}");
    }
}

#[test]
fn host_loss_drill_rejects_single_process_fleets() {
    let registry = fleet_registry();
    let server = PolicyServer::start(Arc::clone(&registry), ServeConfig::default());
    let cfg = FleetConfig {
        robots: 2,
        horizon: 4,
        variants: vec!["dense".into()],
        drills: vec![Drill::HostLoss],
        ..Default::default()
    };
    assert_eq!(
        run_fleet(&registry, &server, &cfg, &ObsParams::clean()).unwrap_err(),
        FleetError::DrillNeedsHosts
    );
    server.shutdown();
}

// ------------------------------------------------------ control pacing

#[test]
fn control_hz_pacing_is_deterministic_and_actually_paces() {
    let registry = fleet_registry();
    let period = Duration::from_millis(20);
    let cfg = FleetConfig {
        robots: 4,
        horizon: 12,
        variants: vec!["dense".into(), "hbvla-packed".into()],
        seed: 61,
        control_period: Some(period),
        ..Default::default()
    };
    let run = |workers: usize| {
        let server = PolicyServer::start(Arc::clone(&registry), serve_cfg(workers));
        let report = run_fleet(&registry, &server, &cfg, &ObsParams::clean()).unwrap();
        server.shutdown();
        report
    };
    let one = run(1);
    let four = run(4);
    assert_accounting_closed(&one);
    assert_accounting_closed(&four);

    // Pacing reshapes WHEN decodes start, never WHAT they compute: the
    // worker-count determinism guarantee must survive intact.
    assert_eq!(one.rows.len(), four.rows.len());
    for (a, b) in one.rows.iter().zip(&four.rows) {
        assert_eq!(a.variant, b.variant);
        assert_eq!(a.digest, b.digest, "pacing broke determinism for '{}'", a.variant);
        assert_eq!(a.submits, b.submits);
        assert_eq!(a.responses_ok, b.responses_ok);
        assert_eq!((a.retries, a.errors, a.dropped), (0, 0, 0));
        assert_eq!((b.retries, b.errors, b.dropped), (0, 0, 0));
    }

    // The pace is real. With zero retries, submits == decode starts; by
    // pigeonhole some robot started at least ceil(total/robots) decodes,
    // and consecutive starts sit >= one control period apart.
    let total_submits: u64 = one.rows.iter().map(|r| r.submits).sum();
    let busiest_floor = (total_submits as usize).div_ceil(cfg.robots);
    assert!(busiest_floor >= 2, "fleet too short to exercise pacing ({total_submits} submits)");
    let min_wall = period.as_secs_f64() * (busiest_floor - 1) as f64;
    assert!(
        one.wall_secs >= min_wall * 0.9,
        "paced fleet finished in {:.3}s, pacing floor is {:.3}s",
        one.wall_secs,
        min_wall
    );
}

#[test]
fn variant_selector_survives_the_wire_by_kind() {
    let named = ServeRequest::new(Observation {
        visual_raw: Matrix::gauss(2, 3, 1.0, &mut Rng::new(9)),
        instr_id: 4,
        proprio: vec![0.5, -0.25],
    })
    .with_variant("hbvla-packed-a8");
    let body = encode_frame(&Frame::Request { id: 1, seq: 2, req: named });
    match decode_frame(&body).unwrap() {
        Frame::Request { req, .. } => {
            assert_eq!(req.variant, VariantSelector::named("hbvla-packed-a8"));
        }
        f => panic!("wrong frame kind: {f:?}"),
    }
}
