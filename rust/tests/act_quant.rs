//! W1A8 parity wall: the i8-activation packed kernels (`matvec_i8` /
//! `matmul_i8`) against the f32 packed kernels and the dense twin, at the
//! kernel level (all tail shapes, pinned error bounds per group size) and
//! end-to-end (every action-head kind, full model forward under
//! `ActPrecision::Int8` vs `F32`). These bounds are the contract the
//! serving `-a8` variants rely on.

use hbvla::model::{ActPrecision, HeadKind, MiniVla, VlaConfig};
use hbvla::quant::packed::PackedBits;
use hbvla::tensor::ops::{matmul, matvec};
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

/// Analytic elementwise bound on the W1A8 deviation from the f32 packed
/// kernel: |Ŵ x − Ŵ x̂|_r ≤ Σ_j |Ŵ_rj| · s_tok/2 (activation round-off
/// pushed through the dequantized weights), with a small float-rounding
/// allowance.
fn row_bounds(p: &PackedBits, scale: f32) -> Vec<f32> {
    let deq = p.dequantize();
    (0..deq.rows)
        .map(|r| 0.5 * scale * deq.row(r).iter().map(|v| v.abs()).sum::<f32>() * 1.001 + 1e-4)
        .collect()
}

#[test]
fn i8_matvec_vs_f32_packed_vs_dense_tail_shapes() {
    // Shapes cover the word-tail case (70 = 64 + 6), group sizes that do
    // not divide the width, and residual-plane chains.
    let cases = [
        (8usize, 64usize, 32usize, 1usize),
        (6, 70, 64, 2),
        (5, 130, 32, 1),
        (4, 70, 70, 2),
        (3, 200, 128, 1),
    ];
    let mut rng = Rng::new(501);
    for &(rows, cols, gs, order) in &cases {
        let w = Matrix::gauss(rows, cols, 1.0, &mut rng);
        let x: Vec<f32> = (0..cols).map(|_| rng.gauss() as f32).collect();
        let p = PackedBits::pack_residual(&w, gs, order, 0.0);
        // f32 packed reference and dense twin of the packed weights.
        let gsums = p.group_sums(&x);
        let mut y32 = vec![0.0f32; rows];
        p.matvec(&x, &gsums, &mut y32);
        let y_dense = matvec(&p.dequantize(), &x);
        // W1A8 path.
        let act = p.quantize_act(&x);
        let mut y8 = vec![0.0f32; rows];
        p.matvec_i8(&act, &mut y8);
        let bounds = row_bounds(&p, act.scale);
        for r in 0..rows {
            assert!(
                (y32[r] - y8[r]).abs() <= bounds[r],
                "({rows},{cols},{gs},{order}) row {r}: f32 {} vs i8 {} (bound {})",
                y32[r],
                y8[r],
                bounds[r]
            );
            // Against the dense twin the i8 path carries both the kernel
            // float noise and the activation round-off.
            assert!(
                (y_dense[r] - y8[r]).abs() <= bounds[r] + 1e-3 * (1.0 + y_dense[r].abs()),
                "({rows},{cols},{gs},{order}) row {r}: dense {} vs i8 {}",
                y_dense[r],
                y8[r]
            );
        }
    }
}

#[test]
fn i8_matmul_vs_dense_gemm_with_pinned_bounds_per_group_size() {
    // The W1A8 GEMM against the dense product of the dequantized
    // weights: elementwise within the analytic activation-round-off bound
    // (per-token scale × row abs-sum), and the whole product within a
    // pinned relative-Frobenius budget per group size. The activation
    // round-off is group-size independent, so the budgets are uniform —
    // and an order of magnitude below what a broken per-group rescale
    // would produce.
    let cases: [(usize, f64); 4] = [(16, 0.03), (32, 0.03), (64, 0.03), (128, 0.03)];
    let mut rng = Rng::new(502);
    for &(gs, max_rel_frob) in &cases {
        let w = Matrix::gauss(12, 130, 1.0, &mut rng);
        let x = Matrix::gauss(130, 7, 1.0, &mut rng);
        let p = PackedBits::pack_residual(&w, gs, 2, 0.0);
        let y8 = p.matmul_i8(&x);
        let deq = p.dequantize();
        let y_dense = matmul(&deq, &x);
        assert_eq!((y8.rows, y8.cols), (12, 7));
        let xt = x.transpose();
        let scales: Vec<f32> = (0..7).map(|t| p.quantize_act(xt.row(t)).scale).collect();
        let abs_rows: Vec<f32> =
            (0..12).map(|r| deq.row(r).iter().map(|v| v.abs()).sum::<f32>()).collect();
        for r in 0..12 {
            for t in 0..7 {
                let (a, b) = (y8.at(r, t), y_dense.at(r, t));
                let bound = 0.5 * scales[t] * abs_rows[r] * 1.001 + 1e-3 * (1.0 + b.abs());
                assert!((a - b).abs() <= bound, "gs={gs} ({r},{t}): i8 {a} vs dense {b}");
            }
        }
        let rel = y8.dist_sq(&y_dense) / y_dense.frob_norm_sq().max(1e-12);
        assert!(
            rel.sqrt() <= max_rel_frob,
            "gs={gs}: W1A8 GEMM relative error {} over pinned budget {max_rel_frob}",
            rel.sqrt()
        );
    }
}

#[test]
fn i8_gemm_columns_equal_i8_gemv() {
    // The GEMM quantizes each token exactly as the GEMV does and shares
    // its accumulation order: columns must match bit-for-bit — the
    // property that makes batched W1A8 serving bit-identical per request.
    let mut rng = Rng::new(503);
    let w = Matrix::gauss(10, 70, 1.0, &mut rng);
    let x = Matrix::gauss(70, 6, 1.0, &mut rng);
    for gs in [64usize, 32, 7] {
        let p = PackedBits::pack_residual(&w, gs, 2, 0.0);
        let y = p.matmul_i8(&x);
        let xt = x.transpose();
        for t in 0..6 {
            let yv = p.matvec_i8_owned(xt.row(t));
            for r in 0..10 {
                assert_eq!(y.at(r, t), yv[r], "gs={gs} ({r},{t})");
            }
        }
    }
}

/// Build (W1A8 model, W1A32 twin) on the same packed store; heads get
/// non-zero weights so decode is exercised.
fn a8_twins(cfg: VlaConfig, group_size: usize) -> (MiniVla, MiniVla) {
    let mut m = MiniVla::new(cfg);
    let mut rng = Rng::new(0x7A18);
    let head_names: Vec<String> = if m.store.contains("head.main") {
        vec!["head.main".to_string()]
    } else {
        (0..m.cfg.diffusion_steps).map(|t| format!("head.diff.{t}")).collect()
    };
    for name in &head_names {
        let (hr, hc) = m.store.dims(name);
        m.store.set(name, Matrix::gauss(hr, hc, 0.05, &mut rng));
    }
    assert!(m.store.pack_quantizable(group_size) > 0, "nothing packed");
    let a32 = m.clone();
    let a8 = m.with_act_precision(ActPrecision::Int8);
    (a8, a32)
}

#[test]
fn w1a8_end_to_end_every_head_within_pinned_bound() {
    // The acceptance bound for the eval drivers: with every quantizable
    // layer packed, switching the store to Int8 activations moves the
    // trunk features by bounded relative noise and every decoded action
    // by less than 0.3 in the [-1, 1] action box (well inside the rollout
    // drivers' tolerance to per-step perturbation, an order of magnitude
    // below what a broken rescale produces).
    for head in [HeadKind::Token, HeadKind::Chunk, HeadKind::Diffusion] {
        let cfg = VlaConfig::tiny(head);
        let (a8, a32) = a8_twins(cfg.clone(), 64);
        assert_eq!(a8.store.act_precision(), ActPrecision::Int8);
        assert_eq!(a32.store.act_precision(), ActPrecision::F32);
        let mut rng = Rng::new(504);
        for trial in 0..3 {
            let v = Matrix::gauss(cfg.d_vis_in, cfg.n_visual, 1.0, &mut rng);
            let p: Vec<f32> = (0..cfg.d_proprio).map(|_| rng.gauss() as f32).collect();
            let f8 = a8.features(&v, 3, &p, &mut None);
            let f32_ = a32.features(&v, 3, &p, &mut None);
            assert_eq!(f8.len(), f32_.len());
            assert!(f8.iter().all(|x| x.is_finite()), "{head:?} trial {trial}: non-finite W1A8");
            let num: f32 = f8.iter().zip(&f32_).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f32 = f32_.iter().map(|b| b * b).sum::<f32>().max(1e-6);
            assert!(
                (num / den).sqrt() < 0.25,
                "{head:?} trial {trial}: feature drift {}",
                (num / den).sqrt()
            );
            let acts8 = a8.decode(&f8, &mut Rng::new(700 + trial));
            let acts32 = a32.decode(&f32_, &mut Rng::new(700 + trial));
            assert_eq!(acts8.len(), acts32.len());
            for (c8, c32) in acts8.iter().zip(&acts32) {
                for (a, b) in c8.iter().zip(c32) {
                    assert!(a.is_finite() && (-1.0..=1.0).contains(a));
                    assert!((a - b).abs() < 0.3, "{head:?} trial {trial}: action {a} vs {b}");
                }
            }
        }
    }
}

#[test]
fn w1a8_tail_width_model_stays_bounded() {
    // d_model = 70 ⇒ every packed layer has a 64 + 6 sign-word tail; the
    // W1A8 forward must stay finite and close to W1A32 there too.
    let mut cfg = VlaConfig::tiny(HeadKind::Chunk);
    cfg.d_model = 70;
    cfg.heads = 2;
    for gs in [64usize, 32] {
        let (a8, a32) = a8_twins(cfg.clone(), gs);
        let mut rng = Rng::new(505);
        let v = Matrix::gauss(cfg.d_vis_in, cfg.n_visual, 1.0, &mut rng);
        let p: Vec<f32> = (0..cfg.d_proprio).map(|_| rng.gauss() as f32).collect();
        let f8 = a8.features(&v, 3, &p, &mut None);
        let f32_ = a32.features(&v, 3, &p, &mut None);
        assert!(f8.iter().all(|x| x.is_finite()), "gs={gs}");
        let num: f32 = f8.iter().zip(&f32_).map(|(a, b)| (a - b) * (a - b)).sum();
        let den: f32 = f32_.iter().map(|b| b * b).sum::<f32>().max(1e-6);
        assert!((num / den).sqrt() < 0.25, "gs={gs}: feature drift {}", (num / den).sqrt());
    }
}
