//! End-to-end integration: checkpoint build → calibrate → quantize →
//! closed-loop evaluation, at reduced budget — the pipeline every
//! table/figure driver runs, exercised as one test.

use hbvla::coordinator::rollout::{eval_tasks, ObsMode, RolloutConfig};
use hbvla::coordinator::scheduler::quantize_model;
use hbvla::eval::harness::{build_testbed, paper_components};
use hbvla::methods::{by_name, paper_methods};
use hbvla::model::HeadKind;
use hbvla::sim::tasks::libero_suite;

fn rollout(eps: usize) -> RolloutConfig {
    RolloutConfig { episodes_per_task: eps, mode: ObsMode::VisualMatching, seed: 2000, threads: 4 }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "heavy; run with --release")]
fn quantize_then_rollout_pipeline() {
    let tasks = libero_suite("object");
    // Seed 11 is the EXPERIMENTS.md reference seed; quantized
    // closed-loop SR has substantial model-seed variance (documented
    // in EXPERIMENTS.md §Variance).
    let tb = build_testbed(HeadKind::Chunk, tasks.clone(), 128, 11);
    let cfg = rollout(4);
    let fp = eval_tasks(&tb.model, &tasks, &cfg);
    assert!(fp.success_rate() > 0.5, "FP checkpoint too weak: {}", fp.success_rate());
    let method = by_name("hbvla").unwrap();
    let (qm, rep) = quantize_model(&tb.model, &tb.calib, method.as_ref(), &paper_components(), 4);
    assert!(rep.mean_rel_err < 0.15, "HBVLA rel err {}", rep.mean_rel_err);
    // The committed model executes on packed 1-bit weights end to end:
    // every quantized layer is WeightRepr::Packed and the store is
    // measurably smaller than its dense twin.
    assert_eq!(rep.packed_layers, rep.layers.len());
    assert!(rep.resident_bytes < rep.dense_bytes);
    // Small (64-dim) layers amortize metadata worse than the paper's
    // 4096-dim LLM layers (~1.08 bpw); see EXPERIMENTS.md §Bits.
    assert!(rep.bits_per_weight() < 6.0, "bpw {}", rep.bits_per_weight());
    let q = eval_tasks(&qm, &tasks, &cfg);
    // The headline property: HBVLA retains a large fraction of FP success.
    assert!(
        q.success_rate() >= 0.3 * fp.success_rate(),
        "HBVLA retention too low: {} vs FP {}",
        q.success_rate(),
        fp.success_rate()
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "heavy; run with --release")]
fn method_error_ordering_on_real_checkpoint() {
    // Weight-space ordering on an actual fitted checkpoint (not synthetic
    // matrices): HBVLA best, BiLLM worst.
    let tasks = libero_suite("object");
    let tb = build_testbed(HeadKind::Chunk, tasks, 24, 7);
    let mut errs = std::collections::HashMap::new();
    for method in paper_methods() {
        let (_, rep) = quantize_model(&tb.model, &tb.calib, method.as_ref(), &paper_components(), 4);
        errs.insert(method.name().to_string(), rep.mean_rel_err);
    }
    assert!(errs["HBVLA"] <= errs["HBLLM"] * 1.05, "{errs:?}");
    assert!(errs["HBVLA"] < errs["BiVLM"], "{errs:?}");
    assert!(errs["BiLLM"] > errs["HBLLM"], "{errs:?}");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "heavy; run with --release")]
fn quantized_models_remain_deterministic() {
    let tasks = libero_suite("goal");
    let tb = build_testbed(HeadKind::Token, tasks.clone(), 16, 3);
    let method = by_name("hbllm").unwrap();
    let (qm, _) = quantize_model(&tb.model, &tb.calib, method.as_ref(), &paper_components(), 2);
    let cfg = rollout(2);
    let a = eval_tasks(&qm, &tasks, &cfg);
    let b = eval_tasks(&qm, &tasks, &cfg);
    assert_eq!(a.successes, b.successes);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "heavy; run with --release")]
fn store_roundtrip_preserves_policy() {
    // Save/load a fitted checkpoint and verify identical behaviour.
    use hbvla::sim::observe::{observe, ObsParams};
    use hbvla::util::rng::Rng;
    let tasks = libero_suite("object");
    let tb = build_testbed(HeadKind::Chunk, tasks.clone(), 16, 5);
    let path = std::env::temp_dir().join("hbvla_ckpt_roundtrip.bin");
    tb.model.store.save(&path).unwrap();
    let loaded = hbvla::model::ParamStore::load(&path).unwrap();
    let mut m2 = tb.model.clone();
    for p in loaded.params() {
        m2.store.set_repr(&p.name, p.repr.clone());
    }
    let mut rng = Rng::new(1);
    let scene = tasks[0].instantiate(&mut rng);
    let obs = observe(&scene, tasks[0].stages[0].instr(), 100, &tb.model, &ObsParams::clean(), &mut rng);
    let f1 = tb.model.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
    let f2 = m2.features(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut None);
    assert_eq!(f1, f2);
    std::fs::remove_file(path).ok();
}
