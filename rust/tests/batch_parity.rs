//! Batched-vs-sequential forward parity: `features_batch`/`decode_batch`
//! must reproduce N independent single-request forwards — bit-identically
//! on a packed model, where every kernel on both paths (packed GEMV and
//! multi-token packed GEMM) shares one accumulation order. This is the
//! property that lets the serving router coalesce requests into one
//! batched packed GEMM without the answer depending on which requests
//! happened to ride in the same batch.

use hbvla::model::{ActPrecision, HeadKind, MiniVla, ObsInput, VlaConfig};
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

/// Build (packed model, dense twin) with every quantizable layer packed at
/// `group_size`; heads get non-zero weights so decode is exercised.
fn twins(cfg: VlaConfig, group_size: usize) -> (MiniVla, MiniVla) {
    let mut packed = MiniVla::new(cfg);
    let mut rng = Rng::new(0x7A17);
    let head_names: Vec<String> = if packed.store.contains("head.main") {
        vec!["head.main".to_string()]
    } else {
        (0..packed.cfg.diffusion_steps).map(|t| format!("head.diff.{t}")).collect()
    };
    for name in &head_names {
        let (hr, hc) = packed.store.dims(name);
        packed.store.set(name, Matrix::gauss(hr, hc, 0.05, &mut rng));
    }
    let n = packed.store.pack_quantizable(group_size);
    assert!(n > 0, "nothing packed");
    let mut dense = packed.clone();
    assert_eq!(dense.store.dequantize_all(), n);
    (packed, dense)
}

/// N random observations with varying instruction ids.
fn rand_batch(cfg: &VlaConfig, n: usize, seed: u64) -> Vec<(Matrix, usize, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|k| {
            let v = Matrix::gauss(cfg.d_vis_in, cfg.n_visual, 1.0, &mut rng);
            let p: Vec<f32> = (0..cfg.d_proprio).map(|_| rng.gauss() as f32).collect();
            (v, k % cfg.vocab, p)
        })
        .collect()
}

fn as_inputs(owned: &[(Matrix, usize, Vec<f32>)]) -> Vec<ObsInput<'_>> {
    owned
        .iter()
        .map(|(v, i, p)| ObsInput { visual_raw: v, instr_id: *i, proprio: p })
        .collect()
}

#[test]
fn features_batch_bit_identical_every_head() {
    // On a packed model AND on its dense twin: the whole trunk routes
    // through the linear() GEMM dispatch on both paths, so the batched
    // trunk is exactly the single-request trunk column-by-column.
    for head in [HeadKind::Token, HeadKind::Chunk, HeadKind::Diffusion] {
        let cfg = VlaConfig::tiny(head);
        let (packed, dense) = twins(cfg.clone(), 64);
        let owned = rand_batch(&cfg, 5, 401);
        let inputs = as_inputs(&owned);
        for model in [&packed, &dense] {
            let singles: Vec<Vec<f32>> = owned
                .iter()
                .map(|(v, i, p)| model.features(v, *i, p, &mut None))
                .collect();
            let batched = model.features_batch(&inputs);
            assert_eq!(batched, singles, "{head:?} batched trunk != sequential trunk");
        }
    }
}

#[test]
fn decode_batch_bit_identical_on_packed_model() {
    // The head layers are packed too, so the batched decode (multi-token
    // packed GEMM) is bit-identical to per-request packed GEMV decodes —
    // including the diffusion head, given per-request noise streams.
    for head in [HeadKind::Chunk, HeadKind::Token, HeadKind::Diffusion] {
        let cfg = VlaConfig::tiny(head);
        let (packed, _) = twins(cfg.clone(), 64);
        let owned = rand_batch(&cfg, 5, 402);
        let feats: Vec<Vec<f32>> = owned
            .iter()
            .map(|(v, i, p)| packed.features(v, *i, p, &mut None))
            .collect();
        let singles: Vec<Vec<Vec<f32>>> = feats
            .iter()
            .enumerate()
            .map(|(r, f)| packed.decode(f, &mut Rng::new(900 + r as u64)))
            .collect();
        let mut rngs: Vec<Rng> = (0..feats.len()).map(|r| Rng::new(900 + r as u64)).collect();
        let batched = packed.decode_batch(&feats, &mut rngs);
        assert_eq!(batched, singles, "{head:?} batched decode != sequential decode");
    }
}

#[test]
fn batch_parity_with_word_tail_widths() {
    // d_model = 70 ⇒ layer widths of 70 = 64 + 6: one full sign word plus
    // a 6-bit tail in every packed row the batch sweeps, with group sizes
    // that do not divide the width.
    let mut cfg = VlaConfig::tiny(HeadKind::Chunk);
    cfg.d_model = 70;
    cfg.heads = 2;
    for gs in [64usize, 32] {
        let (packed, dense) = twins(cfg.clone(), gs);
        let owned = rand_batch(&cfg, 5, 403);
        let inputs = as_inputs(&owned);
        let singles: Vec<Vec<f32>> = owned
            .iter()
            .map(|(v, i, p)| packed.features(v, *i, p, &mut None))
            .collect();
        let batched = packed.features_batch(&inputs);
        assert_eq!(batched, singles, "gs={gs} tail-width batched trunk diverged");
        // Batched decode stays bit-true as well.
        let mut rngs: Vec<Rng> = (0..owned.len()).map(|r| Rng::new(r as u64)).collect();
        let acts_b = packed.decode_batch(&batched, &mut rngs);
        for (r, f) in singles.iter().enumerate() {
            let a = packed.decode(f, &mut Rng::new(r as u64));
            assert_eq!(acts_b[r], a, "gs={gs} request {r} decode diverged");
        }
        // And the batched packed path still tracks the dense twin.
        let batched_dense = dense.features_batch(&inputs);
        for (fp, fd) in batched.iter().zip(&batched_dense) {
            for (a, b) in fp.iter().zip(fd) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "packed {a} vs dense twin {b}");
            }
        }
    }
}

#[test]
fn dense_head_decode_batch_close_to_sequential() {
    // A dense f32 head decodes through a different float-summation order
    // (GEMV's unrolled accumulators vs the GEMM's ikj loop): equal to
    // rounding noise, not bit-equal. Pin the tolerance contract.
    let cfg = VlaConfig::tiny(HeadKind::Chunk);
    let mut model = MiniVla::new(cfg.clone());
    let mut rng = Rng::new(0xD0);
    let (hr, hc) = model.store.dims("head.main");
    model.store.set("head.main", Matrix::gauss(hr, hc, 0.05, &mut rng));
    let owned = rand_batch(&cfg, 4, 404);
    let feats: Vec<Vec<f32>> =
        owned.iter().map(|(v, i, p)| model.features(v, *i, p, &mut None)).collect();
    let mut rngs: Vec<Rng> = (0..feats.len()).map(|r| Rng::new(r as u64)).collect();
    let batched = model.decode_batch(&feats, &mut rngs);
    for (r, f) in feats.iter().enumerate() {
        let single = model.decode(f, &mut Rng::new(r as u64));
        assert_eq!(batched[r].len(), single.len());
        for (ca, cb) in batched[r].iter().zip(&single) {
            for (a, b) in ca.iter().zip(cb) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "request {r}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn w1a8_batch_bit_identical_every_head() {
    // The W1A8 GEMM quantizes and accumulates each token exactly as the
    // W1A8 GEMV does, so a batched Int8-activation forward — trunk AND
    // decode — must reproduce the per-request forwards bit-for-bit, same
    // as the f32 packed contract above.
    for head in [HeadKind::Token, HeadKind::Chunk, HeadKind::Diffusion] {
        let cfg = VlaConfig::tiny(head);
        let (packed, _) = twins(cfg.clone(), 64);
        let a8 = packed.with_act_precision(ActPrecision::Int8);
        let owned = rand_batch(&cfg, 5, 406);
        let inputs = as_inputs(&owned);
        let singles: Vec<Vec<f32>> =
            owned.iter().map(|(v, i, p)| a8.features(v, *i, p, &mut None)).collect();
        let batched = a8.features_batch(&inputs);
        assert_eq!(batched, singles, "{head:?} W1A8 batched trunk != sequential trunk");
        let single_acts: Vec<Vec<Vec<f32>>> = singles
            .iter()
            .enumerate()
            .map(|(r, f)| a8.decode(f, &mut Rng::new(910 + r as u64)))
            .collect();
        let mut rngs: Vec<Rng> = (0..singles.len()).map(|r| Rng::new(910 + r as u64)).collect();
        let batched_acts = a8.decode_batch(&batched, &mut rngs);
        assert_eq!(batched_acts, single_acts, "{head:?} W1A8 batched decode != sequential");
    }
}

#[test]
fn w1a8_batch_parity_with_word_tail_widths() {
    // 70 = 64 + 6 sign-word tails under Int8 activations: the i8 GEMM's
    // masked tail word must agree with the GEMV's bit-for-bit.
    let mut cfg = VlaConfig::tiny(HeadKind::Chunk);
    cfg.d_model = 70;
    cfg.heads = 2;
    for gs in [64usize, 32] {
        let (packed, _) = twins(cfg.clone(), gs);
        let a8 = packed.with_act_precision(ActPrecision::Int8);
        let owned = rand_batch(&cfg, 4, 407);
        let inputs = as_inputs(&owned);
        let singles: Vec<Vec<f32>> =
            owned.iter().map(|(v, i, p)| a8.features(v, *i, p, &mut None)).collect();
        let batched = a8.features_batch(&inputs);
        assert_eq!(batched, singles, "gs={gs} W1A8 tail-width batched trunk diverged");
    }
}

#[test]
fn empty_and_singleton_batches() {
    let cfg = VlaConfig::tiny(HeadKind::Chunk);
    let (packed, _) = twins(cfg.clone(), 64);
    assert!(packed.features_batch(&[]).is_empty());
    assert!(packed.decode_batch(&[], &mut []).is_empty());
    // A batch of one is exactly the single-request forward.
    let owned = rand_batch(&cfg, 1, 405);
    let inputs = as_inputs(&owned);
    let single = packed.features(&owned[0].0, owned[0].1, &owned[0].2, &mut None);
    assert_eq!(packed.features_batch(&inputs), vec![single]);
}
