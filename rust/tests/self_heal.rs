//! Self-healing multi-host serving: the PR-10 contract wall.
//!
//! Three pinned guarantees (ISSUE PR 10), each asserted at worker counts
//! 1 and 4 so self-healing never leans on scheduling luck:
//!
//! 1. **Rejoin**: kill a loopback host, serve on the survivor, revive
//!    the host on its original address — the router's reconnect
//!    supervisor re-dials, the handshake re-arms the slot, placement
//!    snaps variants home, and every action served before, during and
//!    after the outage is bit-identical to a direct in-process forward.
//! 2. **Replica failover**: with `replicas: 2`, killing a host mid-wave
//!    loses NOTHING — every in-flight handle resolves `Ok`, re-served on
//!    the surviving replica under the same router-minted seq, so the
//!    action vectors equal the no-fault direct run bit-for-bit.
//! 3. **Registry hot-swap**: the `variant-kill` drill deregisters a hot
//!    variant mid-run; the fleet ends with the accounting invariant
//!    intact and typed `UnknownVariant` errors only — no hangs, no
//!    panics, and the reference variant's rows stay clean.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hbvla::coordinator::router::LocalCluster;
use hbvla::coordinator::{
    quantize_into_registry, ModelRegistry, PolicyServer, RouterConfig, ServeConfig, ServeRequest,
    ServeResponse,
};
use hbvla::fleet::{run_fleet, Drill, FleetConfig, FleetReport};
use hbvla::methods::traits::Component;
use hbvla::methods::HbVla;
use hbvla::model::{HeadKind, MiniVla, VlaConfig};
use hbvla::sim::observe::{observe, ObsParams, Observation};
use hbvla::sim::tasks::libero_suite;
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

/// Tiny chunk-head checkpoint plus its packed 1-bit commit — the minimal
/// two-variant menu, mirroring tests/multi_host.rs.
fn fleet_registry() -> Arc<ModelRegistry> {
    let mut base = MiniVla::new(VlaConfig::tiny(HeadKind::Chunk));
    let mut rng = Rng::new(0xF1EE7);
    let (hr, hc) = base.store.dims("head.main");
    base.store.set("head.main", Matrix::gauss(hr, hc, 0.1, &mut rng));
    let registry = Arc::new(ModelRegistry::new());
    registry.register("dense", Arc::new(base.clone())).unwrap();
    let comps = [Component::Vision, Component::Language, Component::ActionHead];
    let rep = quantize_into_registry(
        &registry,
        "hbvla-packed",
        &base,
        &HashMap::new(),
        &HbVla::new(),
        &comps,
        2,
    )
    .unwrap();
    assert!(rep.packed_layers > 0, "{rep:?}");
    registry
}

fn sample_obs(model: &MiniVla, seed: u64) -> Observation {
    let task = &libero_suite("object")[0];
    let mut rng = Rng::new(seed);
    let scene = task.instantiate(&mut rng);
    observe(&scene, task.stages[0].instr(), 100, model, &ObsParams::clean(), &mut rng)
}

fn serve_cfg(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        ..Default::default()
    }
}

fn assert_bit_identical(direct: &ServeResponse, routed: &ServeResponse, label: &str) {
    assert_eq!(direct.variant_served, routed.variant_served, "{label}: variant moved");
    assert_eq!(direct.actions.len(), routed.actions.len(), "{label}: chunk length moved");
    for (da, ra) in direct.actions.iter().zip(&routed.actions) {
        assert_eq!(da.len(), ra.len());
        for (x, y) in da.iter().zip(ra) {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: actions diverged");
        }
    }
}

/// Every submit is answered OK or lands in exactly one typed error
/// counter — nothing silent, nothing lost (same closure as tests/fleet.rs).
fn assert_accounting_closed(report: &FleetReport) {
    let mut total_ok = 0;
    for row in &report.rows {
        assert_eq!(
            row.submits,
            row.responses_ok + row.admission_sheds + row.deadline_misses + row.errors,
            "accounting leak in variant '{}': {row:?}",
            row.variant
        );
        total_ok += row.responses_ok;
    }
    assert_eq!(total_ok, report.total_responses);
    assert_eq!(report.rows.iter().map(|r| r.robots).sum::<usize>(), report.robots);
}

fn alternating_requests(model: &MiniVla, base_seed: u64, n: usize) -> Vec<ServeRequest> {
    (0..n)
        .map(|i| {
            let v = if i % 2 == 0 { "dense" } else { "hbvla-packed" };
            ServeRequest::new(sample_obs(model, base_seed + i as u64)).with_variant(v)
        })
        .collect()
}

fn wait_for_live(cluster: &LocalCluster, want: usize, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while cluster.router.live_hosts() != want && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(cluster.router.live_hosts(), want, "router never observed {what}");
}

// -------------------------------------------------------------- rejoin

#[test]
fn killed_host_rejoins_and_actions_stay_bit_identical() {
    for workers in [1usize, 4] {
        let registry = fleet_registry();
        let model = registry.get("dense").unwrap();
        let requests = alternating_requests(&model, 300, 12);

        let server = PolicyServer::start(Arc::clone(&registry), serve_cfg(workers));
        let direct: Vec<ServeResponse> =
            requests.iter().map(|r| server.submit(r.clone()).unwrap()).collect();
        server.shutdown();

        let cluster = LocalCluster::spawn(
            Arc::clone(&registry),
            serve_cfg(workers),
            2,
            RouterConfig::default(),
        )
        .unwrap();

        // Phase 1: healthy cluster, both hosts serving their homes.
        for (i, req) in requests[..4].iter().enumerate() {
            let routed = cluster.router.submit(req.clone()).unwrap();
            assert_bit_identical(&direct[i], &routed, &format!("workers={workers} pre-kill {i}"));
        }

        // Phase 2: kill a host; once the router notices, every variant
        // re-homes onto the survivor and actions do not move a bit.
        let killed = cluster.kill_host().expect("kill_host refused with 2 live hosts");
        wait_for_live(&cluster, 1, "the host death");
        for (i, req) in requests[4..8].iter().enumerate() {
            let routed = cluster.router.submit(req.clone()).unwrap();
            assert_bit_identical(
                &direct[4 + i],
                &routed,
                &format!("workers={workers} during-outage {i}"),
            );
        }

        // Phase 3: revive the host on its ORIGINAL address. The only way
        // live_hosts returns to 2 is the reconnect supervisor re-dialing
        // and completing the hello handshake — so waiting proves rejoin.
        let revived = cluster.revive_host().expect("no dead slot to revive");
        assert_eq!(revived, killed, "revive did not reuse the killed host's address");
        wait_for_live(&cluster, 2, "the rejoin");
        assert!(cluster.router.redials_total() >= 1, "rejoin without a recorded redial");
        let rejoined = cluster
            .router
            .host_counters()
            .into_iter()
            .find(|c| c.redials >= 1)
            .expect("no host slot recorded the redial");
        assert_eq!(rejoined.addr, killed);
        assert!(rejoined.alive);
        assert!(rejoined.last_death_seq.is_some(), "death progress mark missing");
        assert!(rejoined.last_rejoin_seq.is_some(), "rejoin progress mark missing");

        for (i, req) in requests[8..].iter().enumerate() {
            let routed = cluster.router.submit(req.clone()).unwrap();
            assert_bit_identical(
                &direct[8 + i],
                &routed,
                &format!("workers={workers} post-rejoin {i}"),
            );
        }
        cluster.shutdown();
    }
}

// ------------------------------------------------------------ failover

#[test]
fn replica_failover_mid_kill_loses_nothing_and_stays_bit_identical() {
    for workers in [1usize, 4] {
        let registry = fleet_registry();
        let model = registry.get("dense").unwrap();
        let requests = alternating_requests(&model, 400, 36);

        let server = PolicyServer::start(Arc::clone(&registry), serve_cfg(workers));
        let direct: Vec<ServeResponse> =
            requests.iter().map(|r| server.submit(r.clone()).unwrap()).collect();
        server.shutdown();

        let cluster = LocalCluster::spawn(
            Arc::clone(&registry),
            serve_cfg(workers),
            2,
            RouterConfig { replicas: 2, ..Default::default() },
        )
        .unwrap();

        // A whole wave in flight across both replicas, then the kill.
        // Queue depth spreads the wave over both hosts (best_replica
        // scores by local inflight depth), so the victim holds live work.
        let handles: Vec<_> = requests[..32]
            .iter()
            .map(|req| cluster.router.submit_async(req.clone()).unwrap())
            .collect();
        cluster.kill_host().expect("kill_host refused with 2 live hosts");

        // Zero hung handles, zero losses: requests caught on the dying
        // host fail over to the surviving replica under the SAME seq, so
        // every action vector equals the no-fault direct run.
        for (i, h) in handles.into_iter().enumerate() {
            let routed = h.wait().unwrap_or_else(|e| {
                panic!("workers={workers} request {i} lost to the kill: {e:?}")
            });
            assert_bit_identical(&direct[i], &routed, &format!("workers={workers} failover {i}"));
        }
        assert!(
            cluster.router.failovers_total() >= 1,
            "a mid-wave host kill recorded no failovers (workers={workers})"
        );

        // The survivor keeps serving fresh submits after the dust settles.
        wait_for_live(&cluster, 1, "the host death");
        for (i, req) in requests[32..].iter().enumerate() {
            let routed = cluster.router.submit(req.clone()).unwrap();
            assert_bit_identical(
                &direct[32 + i],
                &routed,
                &format!("workers={workers} post-failover {i}"),
            );
        }
        cluster.shutdown();
    }
}

// ------------------------------------------------------- variant-kill

#[test]
fn variant_kill_drill_ends_typed_with_accounting_intact() {
    for workers in [1usize, 4] {
        // Fresh registry per run: the drill really deregisters the variant.
        let registry = fleet_registry();
        let epoch_before = registry.epoch();
        let server = PolicyServer::start(Arc::clone(&registry), serve_cfg(workers));
        let cfg = FleetConfig {
            robots: 8,
            horizon: 12,
            variants: vec!["dense".into(), "hbvla-packed".into()],
            seed: 71,
            drills: vec![Drill::VariantKill],
            ..Default::default()
        };
        let report = run_fleet(&registry, &server, &cfg, &ObsParams::clean()).unwrap();
        server.shutdown();

        // The invariant the drill exists to prove: every submit landed in
        // exactly one typed counter — no hangs, no silent losses.
        assert_accounting_closed(&report);

        let d = &report.drill_report;
        assert_eq!(d.variant_killed.as_deref(), Some("hbvla-packed"), "{d:?}");
        assert_eq!(d.variants_before_kill, 2, "{d:?}");
        assert_eq!(d.variants_after_kill, 1, "{d:?}");
        assert!(
            registry.get("hbvla-packed").is_none(),
            "victim still resolvable after the drill (workers={workers})"
        );
        assert!(
            registry.epoch() > epoch_before,
            "hot-swap remove did not bump the registry epoch"
        );

        // Victim robots die loudly mid-run with typed errors; the
        // reference variant's rows stay spotless.
        let victim = report.rows.iter().find(|r| r.variant == "hbvla-packed").unwrap();
        assert!(victim.responses_ok > 0, "drill fired before the victim ever served: {victim:?}");
        assert!(victim.errors >= 1, "no typed errors despite the mid-run kill: {victim:?}");
        assert!(victim.dropped >= 1, "no robot dropped despite losing its variant: {victim:?}");
        let dense = report.rows.iter().find(|r| r.variant == "dense").unwrap();
        assert_eq!((dense.errors, dense.dropped), (0, 0), "{dense:?}");
        assert!(dense.responses_ok > 0);

        // In-process serving has no router: self-heal counters stay zero.
        assert_eq!((report.router_redials, report.router_failovers), (0, 0));
    }
}
