//! Bench: regenerate Table 1 (SIMPLER, CogACT-mini) end-to-end at bench
//! budget; tune with HBVLA_BENCH_EPISODES / HBVLA_BENCH_DEMOS.
include!("harness_common.rs");

fn main() {
    let budget = smoke_budget();
    bench("table1_simpler (end-to-end)", 0, 1, || {
        for t in hbvla::eval::tables::table1_simpler(&budget) {
            println!("{}", t.render());
        }
    });
}
