//! Bench: regenerate Figure 3 (Mobile-ALOHA suite) end-to-end.
include!("harness_common.rs");

fn main() {
    let budget = smoke_budget();
    bench("fig3_aloha (end-to-end)", 0, 1, || {
        println!("{}", hbvla::eval::figures::fig3_aloha(&budget).render());
    });
}
