//! Bench: regenerate Table 2 (LIBERO, OpenVLA-mini + OFT-mini) end-to-end.
include!("harness_common.rs");

fn main() {
    let budget = smoke_budget();
    bench("table2_libero (end-to-end)", 0, 1, || {
        for t in hbvla::eval::tables::table2_libero(&budget) {
            println!("{}", t.render());
        }
    });
}
