//! Micro/perf benches: PTQ throughput, packed vs dense GEMV/GEMM (with
//! the word-at-a-time vs per-bit kernel speedup), rollout and serving —
//! the §Perf numbers of EXPERIMENTS.md.
include!("harness_common.rs");

use hbvla::quant::packed::PackedBits;
use hbvla::tensor::ops::{matmul, matmul_mt, matvec};
use hbvla::tensor::Matrix;
use hbvla::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(4242);
    // GEMM kernels.
    let a = Matrix::gauss(256, 256, 1.0, &mut rng);
    let b = Matrix::gauss(256, 256, 1.0, &mut rng);
    bench("gemm 256^3 single-thread", 3, 20, || {
        std::hint::black_box(matmul(&a, &b));
    });
    let a2 = Matrix::gauss(1024, 1024, 1.0, &mut rng);
    let b2 = Matrix::gauss(1024, 1024, 1.0, &mut rng);
    bench("gemm 1024^3 multi-thread", 1, 5, || {
        std::hint::black_box(matmul_mt(&a2, &b2, 8));
    });
    // Packed vs dense GEMV.
    let w = Matrix::gauss(512, 2048, 1.0, &mut rng);
    let x: Vec<f32> = (0..2048).map(|_| rng.gauss() as f32).collect();
    let packed = PackedBits::pack(&w, 128);
    let gsums = packed.group_sums(&x);
    let mut y = vec![0.0f32; 512];
    bench("dense GEMV 512x2048", 5, 200, || {
        std::hint::black_box(matvec(&w, &x));
    });
    let t_new = bench("packed 1-bit GEMV 512x2048", 5, 200, || {
        packed.matvec(&x, &gsums, &mut y);
        std::hint::black_box(&y);
    });
    // Inner-loop speedup: word-at-a-time set-bit extraction vs the per-bit
    // shift + sign-XOR reference kernel.
    let t_ref = bench("packed GEMV per-bit reference", 5, 200, || {
        packed.matvec_per_bit(&x, &gsums, &mut y);
        std::hint::black_box(&y);
    });
    println!(
        "[bench] packed GEMV inner loop: per-bit {:.3}ms, word-at-a-time {:.3}ms — speedup ×{:.2}",
        t_ref * 1e3,
        t_new * 1e3,
        t_ref / t_new
    );
    // W1A8: integer inner loops on the same packed weights. The i8 GEMV
    // mirrors the f32 loop's amortization (activation prepared once); the
    // comparison line is the acceptance gate "i8 no slower than f32".
    let act = packed.quantize_act(&x);
    let t_i8 = bench("packed W1A8 GEMV 512x2048 (sliced)", 5, 200, || {
        packed.matvec_i8(&act, &mut y);
        std::hint::black_box(&y);
    });
    println!(
        "[bench] packed GEMV activation precision: f32 {:.3}ms, i8 {:.3}ms — W1A8 ×{:.2}",
        t_new * 1e3,
        t_i8 * 1e3,
        t_new / t_i8
    );
    // Bit-sliced popcount vs trailing_zeros extraction: same packed
    // weights, bit-identical outputs — the inner-loop change alone.
    let t_i8_ext = bench("packed W1A8 GEMV 512x2048 (extraction)", 5, 200, || {
        packed.matvec_i8_extract(&act, &mut y);
        std::hint::black_box(&y);
    });
    println!(
        "[bench] W1A8 inner loop: extraction {:.3}ms, bit-sliced {:.3}ms — sliced ×{:.2}",
        t_i8_ext * 1e3,
        t_i8 * 1e3,
        t_i8_ext / t_i8
    );
    // Wide-lane dispatch: the forced-lane sliced kernel at every lane
    // this machine can run (outputs bit-identical across lanes — only
    // the word-level inner loop differs).
    {
        use hbvla::quant::packed::SimdLane;
        println!("[bench] active SIMD lane: {}", SimdLane::active().label());
        let mut per_lane = Vec::new();
        for lane in SimdLane::available() {
            let t = bench(&format!("packed W1A8 GEMV 512x2048 ({})", lane.label()), 5, 200, || {
                packed.matvec_i8_lane(&act, &mut y, 1, lane);
                std::hint::black_box(&y);
            });
            per_lane.push((lane.label(), t));
        }
        if let Some(&(_, t0)) = per_lane.first() {
            for &(label, t) in per_lane.iter().skip(1) {
                println!(
                    "[bench] W1A8 lane {label}: {:.3}ms vs scalar {:.3}ms — ×{:.2}",
                    t * 1e3,
                    t0 * 1e3,
                    t0 / t
                );
            }
        }
    }
    // Same comparison at a model-shaped layer (d_model-scale GEMV).
    {
        let wm = Matrix::gauss(128, 512, 1.0, &mut rng);
        let pm = PackedBits::pack_residual(&wm, 64, 2, 0.0);
        let xm: Vec<f32> = (0..512).map(|_| rng.gauss() as f32).collect();
        let am = pm.quantize_act(&xm);
        let mut ym = vec![0.0f32; 128];
        let tm_s = bench("packed W1A8 GEMV 128x512 o2 (sliced)", 10, 2000, || {
            pm.matvec_i8(&am, &mut ym);
            std::hint::black_box(&ym);
        });
        let tm_e = bench("packed W1A8 GEMV 128x512 o2 (extraction)", 10, 2000, || {
            pm.matvec_i8_extract(&am, &mut ym);
            std::hint::black_box(&ym);
        });
        println!(
            "[bench] model-shape W1A8 inner loop: extraction {:.4}ms, sliced {:.4}ms — ×{:.2}",
            tm_e * 1e3,
            tm_s * 1e3,
            tm_e / tm_s
        );
    }
    bench("packed W1A8 quantize_act 2048 (fused slice)", 5, 2000, || {
        std::hint::black_box(packed.quantize_act(&x));
    });
    // Static-scale quantization: the max sweep skipped (the
    // ActScaleMode::Static hot path) vs the per-token two-pass form.
    let s_tok = hbvla::tensor::ops::act_scale_i8(&x);
    bench("packed W1A8 quantize_act 2048 (static scale)", 5, 2000, || {
        std::hint::black_box(packed.quantize_act_with_scale(&x, s_tok));
    });
    // Dispatch overhead: persistent-pool parallel_for vs the per-call
    // thread-spawn reference, at tiny n where dispatch dominates.
    {
        use hbvla::util::threadpool::{parallel_for, parallel_for_spawn};
        let sink = std::sync::atomic::AtomicUsize::new(0);
        let t_pool = bench("parallel_for n=8 pooled dispatch", 20, 2000, || {
            parallel_for(8, 8, |i| {
                sink.fetch_add(i, std::sync::atomic::Ordering::Relaxed);
            });
        });
        let t_spawn = bench("parallel_for n=8 per-call spawn", 5, 200, || {
            parallel_for_spawn(8, 8, |i| {
                sink.fetch_add(i, std::sync::atomic::Ordering::Relaxed);
            });
        });
        println!(
            "[bench] parallel_for dispatch: spawn {:.1}us, pool {:.1}us — pool ×{:.1} cheaper",
            t_spawn * 1e6,
            t_pool * 1e6,
            t_spawn / t_pool
        );
    }
    // Transform-domain exact serving: the activation-side costs (permuted
    // gather, in-place Haar forward, fused gather+Haar+quantize_act) and
    // the end-to-end exact GEMV vs the residual-plane repack it replaces.
    {
        use hbvla::quant::transform::{transform_group_size, TransformPacked};
        let mut perm: Vec<usize> = (0..2048).collect();
        rng.shuffle(&mut perm);
        let wp = w.select_cols(&perm);
        let u = hbvla::haar::haar_rows(&wp);
        let tbits = PackedBits::pack(&u, transform_group_size(1024));
        let inv: Vec<u32> = {
            // TransformPacked gathers x_p[k] = x[perm[k]]; reuse the same π.
            perm.iter().map(|&p| p as u32).collect()
        };
        let t = TransformPacked::new(2048, inv, tbits, None);
        let mut xp = vec![0.0f32; 2048];
        bench("transform permuted gather 2048", 5, 2000, || {
            for (k, slot) in xp.iter_mut().enumerate() {
                *slot = x[perm[k]];
            }
            std::hint::black_box(&xp);
        });
        bench("transform haar act fwd 2048 (in-place)", 5, 2000, || {
            let z = hbvla::haar::haar_act_fwd_vec(&xp);
            std::hint::black_box(z);
        });
        bench("transform fused gather+haar 2048", 5, 2000, || {
            std::hint::black_box(t.transform_act(&x));
        });
        bench("transform fused gather+haar+quantize_act 2048", 5, 2000, || {
            std::hint::black_box(t.quantize_transformed(&x));
        });
        let t_exact = bench("transform-exact GEMV 512x2048 (1 plane)", 5, 200, || {
            std::hint::black_box(t.matvec_owned(&x));
        });
        // The deploy form this replaces: residual-plane repack of the same
        // reconstruction, order K ≥ 1 planes.
        let repack = PackedBits::pack_deploy(&t.dequantize());
        let t_repack = bench("repacked residual GEMV 512x2048", 5, 200, || {
            std::hint::black_box(repack.matvec_owned(&x));
        });
        println!(
            "[bench] exact vs repacked GEMV: exact {:.3}ms (1 plane + O(n) transform), \
             repacked {:.3}ms ({} planes) — exact ×{:.2}, memory ×{:.2} smaller",
            t_exact * 1e3,
            t_repack * 1e3,
            repack.order(),
            t_repack / t_exact,
            repack.storage_bytes() as f64 / t.storage_bytes() as f64
        );
    }
    // Packed multi-token GEMM (rows over the thread pool).
    let xb = Matrix::gauss(2048, 16, 1.0, &mut rng);
    bench("dense GEMM 512x2048x16 mt", 2, 30, || {
        std::hint::black_box(matmul_mt(&w, &xb, 8));
    });
    bench("packed 1-bit GEMM 512x2048x16 mt", 2, 30, || {
        std::hint::black_box(packed.matmul_mt(&xb, 8));
    });
    bench("packed W1A8 GEMM 512x2048x16 mt (sliced)", 2, 30, || {
        std::hint::black_box(packed.matmul_i8_mt(&xb, 8));
    });
    bench("packed W1A8 GEMM 512x2048x16 mt (extraction)", 2, 30, || {
        std::hint::black_box(packed.matmul_i8_extract_mt(&xb, 8));
    });
    println!("packed memory ratio: ×{:.1}", packed.compression_ratio());
    // Full §Perf driver.
    let rep = hbvla::eval::perf::run_perf(hbvla::util::threadpool::default_threads(), 11);
    println!("{}", rep.render());
}
