//! Bench: Table 4 ablation (standard vs policy-aware Hessian).
include!("harness_common.rs");

fn main() {
    let budget = smoke_budget();
    bench("table4_hessian", 0, 1, || {
        println!("{}", hbvla::eval::ablation::table4_hessian(&budget).render());
    });
    let (transform, obq) = hbvla::eval::ablation::ablation_obq(&budget);
    println!("extra ablation — Fig-2 transform {transform:.2}% vs Eq-28 OBQ {obq:.2}% (error ↓)");
}
