//! Bench: Table 3 ablation (permutation column-norm criterion ℓ1 vs ℓ2).
include!("harness_common.rs");

fn main() {
    let budget = smoke_budget();
    bench("table3_permutation", 0, 1, || {
        println!("{}", hbvla::eval::ablation::table3_permutation(&budget).render());
    });
}
