// Shared mini-harness for the benches (criterion is unavailable offline):
// wall-clock a closure with warmup, report mean/min over iterations and
// return the mean (for derived figures like speedup ratios).
// Included into each bench via `include!`.

#[allow(dead_code)]
pub fn bench<F: FnMut()>(label: &str, warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("[bench] {label:<40} mean {mean:>9.4}s  min {min:>9.4}s  (n={iters})");
    mean
}

#[allow(dead_code)]
pub fn smoke_budget() -> hbvla::eval::tables::EvalBudget {
    let mut b = hbvla::eval::tables::EvalBudget::smoke();
    b.episodes_per_task = std::env::var("HBVLA_BENCH_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    b.n_demos = std::env::var("HBVLA_BENCH_DEMOS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    b
}
