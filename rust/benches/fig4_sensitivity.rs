//! Bench: regenerate Figure 4 (component sensitivity) + Figure 1 stats.
include!("harness_common.rs");

fn main() {
    let budget = smoke_budget();
    let s = hbvla::eval::figures::fig1_dual_dominance(&budget);
    println!("fig1: max|act|={:.1} kurtosis={:.1} visual:instr={}:1", s.max_abs, s.kurtosis, s.visual_token_ratio);
    bench("fig4_sensitivity (end-to-end)", 0, 1, || {
        println!("{}", hbvla::eval::figures::fig4_sensitivity(&budget).render());
    });
}
