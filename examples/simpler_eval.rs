//! SIMPLER benchmark evaluation (paper Table 1), reduced budget by default.
//!
//! ```bash
//! cargo run --release --example simpler_eval -- [--episodes 50]
//! ```

use hbvla::eval::tables::{table1_simpler, EvalBudget};
use hbvla::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let budget = EvalBudget {
        episodes_per_task: args.usize_or("episodes", 10),
        n_demos: args.usize_or("demos", 128),
        seed: args.u64_or("seed", 2026),
        threads: args.usize_or("threads", hbvla::util::threadpool::default_threads()),
    };
    for t in table1_simpler(&budget) {
        println!("{}", t.render());
    }
}
