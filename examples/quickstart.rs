//! Quickstart: quantize a MiniVLA checkpoint with HBVLA and inspect the
//! result — the five-minute tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hbvla::calib::capture::{capture_calibration, CaptureConfig};
use hbvla::calib::demos::collect_demos;
use hbvla::coordinator::scheduler::quantize_model;
use hbvla::methods::{by_name, paper_methods};
use hbvla::model::{HeadKind, MiniVla, VlaConfig};
use hbvla::quant::packed::PackedBits;
use hbvla::sim::tasks::libero_suite;
use hbvla::train::bc::fit_policy;

fn main() {
    // 1. Build a MiniVLA "checkpoint": structured weights + BC-fit head.
    let mut model = MiniVla::new(VlaConfig::base(HeadKind::Chunk));
    let tasks = libero_suite("object");
    let demos = collect_demos(&model, &tasks, 32, 7);
    let fit = fit_policy(&mut model, &demos, 1.0);
    println!("checkpoint: {} params, BC action MSE {:.4}", model.store.total_weights(), fit.train_metric);

    // 2. Calibrate: standard + policy-aware rectified Hessians per layer.
    let calib = capture_calibration(&model, &demos, &CaptureConfig::default());
    println!("calibrated {} layers", calib.len());

    // 3. Quantize the vision + language backbones with every method.
    let comps = hbvla::eval::paper_components();
    for method in paper_methods() {
        let (_, rep) = quantize_model(&model, &calib, method.as_ref(), &comps, 4);
        println!(
            "{:<8} mean rel err {:.4}  bits/weight {:.3}  ({:.2}s)",
            rep.method,
            rep.mean_rel_err,
            rep.bits_per_weight(),
            rep.wall_secs
        );
    }

    // 4. Deploy-path storage: pack a layer to true 1-bit bitplanes.
    let (qm, _) = quantize_model(&model, &calib, by_name("hbvla").unwrap().as_ref(), &comps, 4);
    let w = qm.store.get("lm.0.wv");
    let packed = PackedBits::pack(w, 128);
    println!(
        "lm.0.wv packed: {} B vs {} B dense (×{:.1} smaller)",
        packed.storage_bytes(),
        packed.dense_bytes(),
        packed.compression_ratio()
    );
}
