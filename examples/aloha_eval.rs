//! Mobile-ALOHA real-world suite evaluation (paper Figure 3).
//!
//! ```bash
//! cargo run --release --example aloha_eval -- [--episodes 50]
//! ```

use hbvla::eval::figures::fig3_aloha;
use hbvla::eval::tables::EvalBudget;
use hbvla::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let budget = EvalBudget {
        episodes_per_task: args.usize_or("episodes", 10),
        n_demos: args.usize_or("demos", 128),
        seed: args.u64_or("seed", 2026),
        threads: args.usize_or("threads", hbvla::util::threadpool::default_threads()),
    };
    println!("{}", fig3_aloha(&budget).render());
}
