//! Component-wise quantization sensitivity (paper Figure 4) plus the
//! dual-dominance statistics (Figure 1).
//!
//! ```bash
//! cargo run --release --example sensitivity -- [--episodes 50]
//! ```

use hbvla::eval::figures::{fig1_dual_dominance, fig4_sensitivity};
use hbvla::eval::tables::EvalBudget;
use hbvla::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let budget = EvalBudget {
        episodes_per_task: args.usize_or("episodes", 10),
        n_demos: args.usize_or("demos", 128),
        seed: args.u64_or("seed", 2026),
        threads: args.usize_or("threads", hbvla::util::threadpool::default_threads()),
    };
    let s = fig1_dual_dominance(&budget);
    println!("## Figure 1 — dual dominance");
    println!("max |activation| {:.1}, kurtosis {:.1}, visual:instr {}:1\n", s.max_abs, s.kurtosis, s.visual_token_ratio);
    println!("{}", fig4_sensitivity(&budget).render());
}
