//! Serving-router demo: batched policy inference with latency stats, and
//! (when `artifacts/` exist) the PJRT path executing the AOT-lowered
//! JAX/Pallas policy graph — proving Python never runs at request time.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_demo
//! ```

use std::sync::Arc;

use hbvla::calib::demos::collect_demos;
use hbvla::coordinator::server::{PolicyServer, ServeConfig};
use hbvla::model::{HeadKind, MiniVla, VlaConfig};
use hbvla::runtime::{artifacts_dir, PolicyRuntime};
use hbvla::sim::observe::{observe, ObsParams};
use hbvla::sim::tasks::libero_suite;
use hbvla::train::bc::fit_policy;
use hbvla::util::rng::Rng;

fn main() {
    let mut model = MiniVla::new(VlaConfig::base(HeadKind::Chunk));
    let tasks = libero_suite("object");
    let demos = collect_demos(&model, &tasks, 32, 7);
    fit_policy(&mut model, &demos, 1.0);
    let model = Arc::new(model);

    // --- Rust-native serving ---
    let server = PolicyServer::start(Arc::clone(&model), ServeConfig::default());
    let mut rng = Rng::new(9);
    let scene = tasks[0].instantiate(&mut rng);
    let obs = observe(&scene, tasks[0].stages[0].instr(), 100, &model, &ObsParams::clean(), &mut rng);
    let n = 500;
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        let _ = server.submit(obs.clone());
    }
    let el = t0.elapsed().as_secs_f64();
    println!("native serving: {n} requests in {el:.3}s ({:.0} req/s)", n as f64 / el);
    println!("  latency {}", server.latency_stats().summary());
    server.shutdown();

    // --- PJRT path (AOT JAX/Pallas graph) ---
    match PolicyRuntime::load(&artifacts_dir()) {
        Ok(rt) => {
            let t1 = std::time::Instant::now();
            let reps = 50;
            let mut last = Vec::new();
            for _ in 0..reps {
                last = rt.step(&model, &obs.visual_raw, obs.instr_id, &obs.proprio).expect("pjrt step");
            }
            let per = t1.elapsed().as_secs_f64() / reps as f64;
            // Parity check against the native forward.
            let native = model.act(&obs.visual_raw, obs.instr_id, &obs.proprio, &mut rng);
            let mut max_diff = 0.0f32;
            for (a, b) in last.iter().flatten().zip(native.iter().flatten()) {
                max_diff = max_diff.max((a - b).abs());
            }
            println!("pjrt serving:  {:.2} ms/step, max action diff vs native = {max_diff:.5}", per * 1e3);
        }
        Err(e) => println!("pjrt path skipped ({e}); run `make artifacts` first"),
    }
}
