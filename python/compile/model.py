"""L2: the MiniVLA policy-step graph in JAX, mirroring `rust/src/model`
operation-for-operation (RMS-norm floor, tanh-GELU, attention layout with
tokens as columns, head expansion + scale normalization, chunk decode).

Weights arrive as *inputs* (a flat ordered list), so the Rust runtime can
feed FP or quantized tensors per call without recompiling. The parameter
order is defined by `weight_names()` and written to
`artifacts/policy_step.inputs.txt` by aot.py; `rust/src/runtime/pjrt.rs`
reads the manifest and feeds its ParamStore in the same order.
"""

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class Config:
    """Mirror of `VlaConfig::base(HeadKind::Chunk)` in rust/src/model."""

    d_vision: int = 48
    vision_blocks: int = 2
    d_model: int = 64
    lm_blocks: int = 3
    heads: int = 4
    mlp_mult: int = 2
    d_vis_in: int = 24
    n_visual: int = 10
    vocab: int = 64
    d_proprio: int = 12
    act_dim: int = 3
    chunk: int = 4
    head_hidden: int = 96

    @property
    def feat_dim(self):
        return 2 * (self.d_model + self.d_proprio)

    @property
    def head_in_dim(self):
        return self.feat_dim + self.head_hidden


def weight_names(cfg: Config):
    """Flat weight-input order — must match the Rust ParamStore names."""
    names = ["vis.embed"]
    for b in range(cfg.vision_blocks):
        names += [f"vis.{b}.{w}" for w in ("wq", "wk", "wv", "wo", "w1", "w2")]
    names += ["proj", "lm.embed_instr", "lm.embed_proprio"]
    for b in range(cfg.lm_blocks):
        names += [f"lm.{b}.{w}" for w in ("wq", "wk", "wv", "wo", "w1", "w2")]
    names += ["head.expand", "head.norm", "head.main"]
    return names


def rmsnorm_cols(x):
    """Column (token) RMS norm with the 0.05 floor (see rust layers.rs)."""
    ms = jnp.mean(x * x, axis=0, keepdims=True)
    return x / jnp.sqrt(ms + 0.05)


def gelu_tanh(x):
    c = 0.7978845608028654  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def attn(wq, wk, wv, wo, heads, x):
    """Multi-head self-attention, tokens as columns: returns x + MHSA(x)."""
    d, n = x.shape
    dh = d // heads
    q = wq @ x
    k = wk @ x
    v = wv @ x
    ctx_parts = []
    for h in range(heads):
        sl = slice(h * dh, (h + 1) * dh)
        s = (q[sl].T @ k[sl]) / jnp.sqrt(jnp.float32(dh))
        p = jnp.exp(s - s.max(axis=1, keepdims=True))
        p = p / p.sum(axis=1, keepdims=True)
        ctx_parts.append(v[sl] @ p.T)
    ctx = jnp.concatenate(ctx_parts, axis=0)
    return x + wo @ ctx


def block(params, prefix, heads, x):
    h = attn(params[f"{prefix}.wq"], params[f"{prefix}.wk"], params[f"{prefix}.wv"],
             params[f"{prefix}.wo"], heads, x)
    h = rmsnorm_cols(h)
    out = h + params[f"{prefix}.w2"] @ gelu_tanh(params[f"{prefix}.w1"] @ h)
    return rmsnorm_cols(out)


def policy_step(cfg: Config, visual_raw, instr_onehot, proprio, *weights):
    """Full policy step: observation → action chunk (chunk × act_dim),
    flattened. Mirrors MiniVla::features + decode (Chunk head)."""
    params = dict(zip(weight_names(cfg), weights))

    xv = rmsnorm_cols(params["vis.embed"] @ visual_raw)
    for b in range(cfg.vision_blocks):
        xv = block(params, f"vis.{b}", cfg.heads, xv)

    xp = rmsnorm_cols(params["proj"] @ xv)

    instr_col = params["lm.embed_instr"] @ instr_onehot
    prop_col = params["lm.embed_proprio"] @ proprio
    seq = jnp.concatenate([xp, instr_col[:, None], prop_col[:, None]], axis=1)
    seq = rmsnorm_cols(seq)
    for b in range(cfg.lm_blocks):
        seq = block(params, f"lm.{b}", cfg.heads, seq)

    held = proprio[3]
    base = jnp.concatenate([seq[:, cfg.n_visual], proprio])
    feat = jnp.concatenate([base, held * base])

    # Head: tanh expansion, scale normalization, linear chunk decode.
    expand = jnp.tanh(params["head.expand"] @ feat)
    hf = jnp.concatenate([feat, expand])
    norm = params["head.norm"]  # (2, head_in): row0 mean (0), row1 scale
    hf = (hf - norm[0]) / jnp.maximum(norm[1], 1e-4)
    out = params["head.main"] @ hf
    return (jnp.clip(out, -1.0, 1.0),)
