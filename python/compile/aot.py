"""AOT lowering: JAX/Pallas → HLO **text** artifacts for the Rust runtime.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts:
  policy_step.hlo.txt / policy_step.inputs.txt  — the L2 policy graph
  binary_linear.hlo.txt                          — L1 binary-GEMV kernel
  haar_fwd.hlo.txt                               — L1 Haar kernel

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.kernels.binary_matmul import binary_matmul
from compile.kernels.haar import haar_fwd
from compile.model import Config, policy_step, weight_names


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def weight_shapes(cfg: Config):
    """Shapes for each entry of weight_names(cfg) (rows, cols)."""
    hid_v = cfg.mlp_mult * cfg.d_vision
    hid_m = cfg.mlp_mult * cfg.d_model
    shapes = {"vis.embed": (cfg.d_vision, cfg.d_vis_in)}
    for b in range(cfg.vision_blocks):
        shapes[f"vis.{b}.wq"] = (cfg.d_vision, cfg.d_vision)
        shapes[f"vis.{b}.wk"] = (cfg.d_vision, cfg.d_vision)
        shapes[f"vis.{b}.wv"] = (cfg.d_vision, cfg.d_vision)
        shapes[f"vis.{b}.wo"] = (cfg.d_vision, cfg.d_vision)
        shapes[f"vis.{b}.w1"] = (hid_v, cfg.d_vision)
        shapes[f"vis.{b}.w2"] = (cfg.d_vision, hid_v)
    shapes["proj"] = (cfg.d_model, cfg.d_vision)
    shapes["lm.embed_instr"] = (cfg.d_model, cfg.vocab)
    shapes["lm.embed_proprio"] = (cfg.d_model, cfg.d_proprio)
    for b in range(cfg.lm_blocks):
        shapes[f"lm.{b}.wq"] = (cfg.d_model, cfg.d_model)
        shapes[f"lm.{b}.wk"] = (cfg.d_model, cfg.d_model)
        shapes[f"lm.{b}.wv"] = (cfg.d_model, cfg.d_model)
        shapes[f"lm.{b}.wo"] = (cfg.d_model, cfg.d_model)
        shapes[f"lm.{b}.w1"] = (hid_m, cfg.d_model)
        shapes[f"lm.{b}.w2"] = (cfg.d_model, hid_m)
    shapes["head.expand"] = (cfg.head_hidden, cfg.feat_dim)
    shapes["head.norm"] = (2, cfg.head_in_dim)
    shapes["head.main"] = (cfg.chunk * cfg.act_dim, cfg.head_in_dim)
    return shapes


def lower_policy(cfg: Config):
    names = weight_names(cfg)
    shapes = weight_shapes(cfg)
    spec = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
    args = [
        spec((cfg.d_vis_in, cfg.n_visual)),
        spec((cfg.vocab,)),
        spec((cfg.d_proprio,)),
    ] + [spec(shapes[n]) for n in names]
    fn = functools.partial(policy_step, cfg)
    return jax.jit(fn).lower(*args), names


def lower_binary_linear():
    rows, cols, gs = 128, 256, 128
    groups = cols // gs

    def fn(signs, alpha, mu, x):
        return (binary_matmul(signs, alpha, mu, x, group_size=gs, block_rows=128),)

    spec = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
    return jax.jit(fn).lower(spec((rows, cols)), spec((rows, groups)), spec((rows, groups)), spec((cols,)))


def lower_haar():
    def fn(w):
        return (haar_fwd(w, block_rows=64),)

    return jax.jit(fn).lower(jax.ShapeDtypeStruct((64, 128), jnp.float32))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    cfg = Config()

    lowered, names = lower_policy(cfg)
    with open(os.path.join(args.out_dir, "policy_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    with open(os.path.join(args.out_dir, "policy_step.inputs.txt"), "w") as f:
        f.write("\n".join(names) + "\n")
    print(f"wrote policy_step ({len(names)} weight inputs)")

    with open(os.path.join(args.out_dir, "binary_linear.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lower_binary_linear()))
    print("wrote binary_linear")

    with open(os.path.join(args.out_dir, "haar_fwd.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lower_haar()))
    print("wrote haar_fwd")


if __name__ == "__main__":
    main()
