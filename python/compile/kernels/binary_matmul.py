"""L1 Pallas kernel: group-dequantized binary matmul (the deploy-path GEMV).

TPU mapping (DESIGN.md §Hardware-Adaptation): signs are fed as ±1-valued
f32/bf16 blocks so the MXU multiplies them natively; the per-group (α, μ)
dequantization is a VPU epilogue fused after the systolic pass:

    y[r] = Σ_g  μ[r,g]·Σ_{j∈g} x_j  +  α[r,g]·Σ_{j∈g} signs[r,j]·x_j

BlockSpec tiles rows; the full K dimension of one row block plus its scale
vectors fit comfortably in VMEM at the paper's layer sizes (§Perf
estimates the footprint). On this image the kernel runs under
`interpret=True` — the CPU PJRT client cannot execute Mosaic custom calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(signs_ref, alpha_ref, mu_ref, x_ref, o_ref, *, group_size):
    signs = signs_ref[...]  # (block_rows, cols)
    x = x_ref[...]  # (cols,)
    alpha = alpha_ref[...]  # (block_rows, groups)
    mu = mu_ref[...]
    rows, cols = signs.shape
    groups = alpha.shape[1]
    # Signed partial sums per group: reshape K into (groups, group_size).
    sx = (signs * x[None, :]).reshape(rows, groups, group_size).sum(axis=2)
    gs = x.reshape(groups, group_size).sum(axis=1)  # per-group Σx (shared)
    o_ref[...] = (alpha * sx).sum(axis=1) + (mu * gs[None, :]).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("group_size", "block_rows"))
def binary_matmul(signs, alpha, mu, x, group_size=128, block_rows=128):
    """y = (μ + α·signs) x with per-group scales. cols must be a multiple
    of group_size and rows a multiple of block_rows (pad upstream)."""
    rows, cols = signs.shape
    groups = cols // group_size
    assert cols % group_size == 0, "pad cols to the group size"
    assert rows % block_rows == 0, "pad rows to the row block"
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, groups), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, groups), lambda r: (r, 0)),
            pl.BlockSpec((cols,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda r: (r,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(signs, alpha, mu, x)
