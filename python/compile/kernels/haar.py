"""L1 Pallas kernel: one-level Haar analysis (row-wise, stride-2 pairs).

Bandwidth-bound; expressed as a reshape-to-pairs + axis reduction so the
TPU lowering is pure VPU adds (DESIGN.md §Hardware-Adaptation). Runs under
interpret=True on this image.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, o_ref):
    w = w_ref[...]  # (block_rows, cols)
    rows, cols = w.shape
    pairs = w.reshape(rows, cols // 2, 2)
    lo = 0.5 * (pairs[:, :, 0] + pairs[:, :, 1])
    hi = 0.5 * (pairs[:, :, 0] - pairs[:, :, 1])
    o_ref[...] = jnp.concatenate([lo, hi], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def haar_fwd(w, block_rows=64):
    """Row-wise one-level Haar transform; cols must be even, rows a
    multiple of block_rows (pad upstream)."""
    rows, cols = w.shape
    assert cols % 2 == 0
    assert rows % block_rows == 0
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, cols), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(w)
