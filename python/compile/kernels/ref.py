"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact jnp counterpart here;
pytest sweeps shapes/dtypes with hypothesis and asserts allclose.
"""

import jax.numpy as jnp


def binary_matmul_ref(signs, alpha, mu, x, group_size):
    """Group-dequantized binary GEMV: y = Ŵ x.

    Ŵ[r, j] = mu[r, g] + alpha[r, g] * signs[r, j]   with g = j // group_size.

    signs: (rows, cols) ±1 values; alpha, mu: (rows, n_groups); x: (cols,).
    """
    rows, cols = signs.shape
    groups = -(-cols // group_size)
    # Broadcast group scales up to per-column resolution.
    gidx = jnp.arange(cols) // group_size
    a = alpha[:, gidx]  # (rows, cols)
    m = mu[:, gidx]
    w_hat = m + a * signs
    return w_hat.astype(jnp.float32) @ x.astype(jnp.float32)


def haar_fwd_ref(w):
    """One-level Haar analysis along the last axis (even length):
    output [lo | hi] with lo = (even+odd)/2, hi = (even−odd)/2 —
    the paper's h_lo=[.5,.5], h_hi=[.5,−.5] stride-2 convolutions."""
    even = w[..., 0::2]
    odd = w[..., 1::2]
    lo = 0.5 * (even + odd)
    hi = 0.5 * (even - odd)
    return jnp.concatenate([lo, hi], axis=-1)


def haar_inv_ref(c):
    """Inverse of haar_fwd_ref: pairwise reconstruction."""
    j = c.shape[-1] // 2
    lo = c[..., :j]
    hi = c[..., j:]
    even = lo + hi
    odd = lo - hi
    out = jnp.stack([even, odd], axis=-1)
    return out.reshape(*c.shape[:-1], 2 * j)
