"""L2 checks: the JAX MiniVLA policy-step graph — shapes, invariances and
numeric properties the Rust runtime relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_policy, weight_shapes
from compile.model import Config, gelu_tanh, policy_step, rmsnorm_cols, weight_names


CFG = Config()


def make_weights(rng, cfg=CFG, scale=0.1):
    shapes = weight_shapes(cfg)
    ws = []
    for n in weight_names(cfg):
        w = jnp.asarray(rng.standard_normal(shapes[n]), dtype=jnp.float32) * scale
        if n == "head.norm":
            w = jnp.ones(shapes[n], dtype=jnp.float32).at[0].set(0.0)
        ws.append(w)
    return ws


def make_obs(rng, cfg=CFG):
    visual = jnp.asarray(rng.standard_normal((cfg.d_vis_in, cfg.n_visual)), dtype=jnp.float32)
    onehot = jnp.zeros((cfg.vocab,), dtype=jnp.float32).at[5].set(1.0)
    prop = jnp.asarray(rng.standard_normal((cfg.d_proprio,)), dtype=jnp.float32)
    return visual, onehot, prop


def test_policy_step_shape_and_range():
    rng = np.random.default_rng(0)
    (out,) = policy_step(CFG, *make_obs(rng), *make_weights(rng))
    assert out.shape == (CFG.chunk * CFG.act_dim,)
    assert bool(jnp.all(jnp.abs(out) <= 1.0))
    assert bool(jnp.all(jnp.isfinite(out)))


def test_policy_step_deterministic():
    rng = np.random.default_rng(1)
    obs = make_obs(rng)
    ws = make_weights(rng)
    (a,) = policy_step(CFG, *obs, *ws)
    (b,) = policy_step(CFG, *obs, *ws)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weights_are_inputs_not_constants():
    rng = np.random.default_rng(2)
    obs = make_obs(rng)
    ws = make_weights(rng)
    (a,) = policy_step(CFG, *obs, *ws)
    ws2 = list(ws)
    ws2[-1] = ws2[-1] * 2.0  # head.main
    (b,) = policy_step(CFG, *obs, *ws2)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_rmsnorm_floor_keeps_silent_tokens_small():
    x = jnp.full((64, 1), 0.01, dtype=jnp.float32)
    y = rmsnorm_cols(x)
    assert float(jnp.abs(y).max()) < 0.1
    x2 = jnp.asarray(np.random.default_rng(3).standard_normal((64, 4)) * 4.0, dtype=jnp.float32)
    y2 = rmsnorm_cols(x2)
    ms = np.asarray(jnp.mean(y2 * y2, axis=0))
    assert np.all(np.abs(ms - 1.0) < 0.05)


def test_gelu_matches_rust_constants():
    x = jnp.array([0.0, 1.0, -1.0, 3.0], dtype=jnp.float32)
    y = np.asarray(gelu_tanh(x))
    np.testing.assert_allclose(y, [0.0, 0.8412, -0.1588, 2.9964], atol=1e-3)


def test_lowering_produces_hlo_text():
    lowered, names = lower_policy(CFG)
    from compile.aot import to_hlo_text

    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(names) == 1 + 6 * CFG.vision_blocks + 3 + 6 * CFG.lm_blocks + 3
    # 3 obs inputs + weights; parameter count appears in the text.
    assert text.count("parameter(") >= len(names)


def test_weight_manifest_matches_rust_store_layout():
    names = weight_names(CFG)
    assert names[0] == "vis.embed"
    assert "lm.0.wq" in names
    assert names[-1] == "head.main"
    assert names[-2] == "head.norm"
    # No duplicates.
    assert len(set(names)) == len(names)
