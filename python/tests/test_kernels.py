"""L1 correctness: Pallas kernels vs pure-jnp oracles, hypothesis-swept."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.binary_matmul import binary_matmul
from compile.kernels.haar import haar_fwd
from compile.kernels.ref import binary_matmul_ref, haar_fwd_ref, haar_inv_ref


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    row_blocks=st.integers(1, 3),
    col_groups=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_binary_matmul_matches_ref(row_blocks, col_groups, seed):
    gs, br = 128, 128
    rows, cols = row_blocks * br, col_groups * gs
    rng = np.random.default_rng(seed)
    signs = jnp.sign(rand(rng, rows, cols)) + (rand(rng, rows, cols) == 0)
    alpha = jnp.abs(rand(rng, rows, cols // gs))
    mu = rand(rng, rows, cols // gs) * 0.1
    x = rand(rng, cols)
    y = binary_matmul(signs, alpha, mu, x, group_size=gs, block_rows=br)
    y_ref = binary_matmul_ref(signs, alpha, mu, x, gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    row_blocks=st.integers(1, 2),
    half_cols=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_haar_fwd_matches_ref(row_blocks, half_cols, seed):
    rows, cols = row_blocks * 64, 2 * half_cols
    rng = np.random.default_rng(seed)
    w = rand(rng, rows, cols)
    out = haar_fwd(w, block_rows=64)
    ref = haar_fwd_ref(w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)


def test_haar_roundtrip():
    rng = np.random.default_rng(7)
    w = rand(rng, 64, 128)
    c = haar_fwd(w)
    back = haar_inv_ref(c)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w), rtol=1e-5, atol=1e-6)


def test_haar_known_values():
    w = jnp.zeros((64, 4), dtype=jnp.float32).at[0].set(jnp.array([4.0, 2.0, -1.0, 3.0]))
    c = haar_fwd(w)
    np.testing.assert_allclose(np.asarray(c[0]), [3.0, 1.0, 1.0, -2.0], atol=1e-6)


def test_binary_matmul_zero_mu_pure_sign():
    rng = np.random.default_rng(3)
    rows, cols, gs = 128, 128, 128
    signs = jnp.sign(rand(rng, rows, cols)) + (rand(rng, rows, cols) == 0)
    alpha = jnp.ones((rows, 1), dtype=jnp.float32)
    mu = jnp.zeros((rows, 1), dtype=jnp.float32)
    x = rand(rng, cols)
    y = binary_matmul(signs, alpha, mu, x, group_size=gs, block_rows=128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(signs @ x), rtol=2e-4, atol=2e-4)


def test_kernels_jit_compile_once():
    # Smoke: jitted kernels are callable twice without error (cache path).
    rng = np.random.default_rng(5)
    w = rand(rng, 64, 8)
    a = haar_fwd(w)
    b = haar_fwd(w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    assert jax.devices()[0].platform == "cpu"
